"""GopherService: warm serving, source-axis batching, continuous batching.

Contracts pinned here:

* batching invisibility — results delivered through the service (any mix
  of analytics, any batch the admission loop happens to form) are bitwise
  identical to plain cold-session runs of the same queries.
* source-axis merging — same-analytic scalar-source queries coalesce into
  one multi-source plan ONLY when every other parameter agrees; an atomic
  ``submit_many`` on an idle service forms exactly one admission.
* warm cache — a repeated query re-stages zero bytes (the session-level
  staging cache holds the batch across requests); ``prestage`` moves the
  staging cost ahead of the first query.
* request plumbing — bad requests raise on the caller's thread, engine
  failures are delivered through ``wait()`` (the loop survives), ``stop``
  drains what was already queued, concurrent submitters all get their
  own correct answers.
"""
import threading

import numpy as np
import pytest

from repro.core.blocked import build_blocked
from repro.core.graph import GraphTemplate
from repro.gopher import GopherService, GopherSession


V, E, I, P, B = 64, 200, 5, 4, 16


def _arrays(seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, V, E)
    dst = rng.integers(0, V, E)
    w = rng.uniform(0.5, 2.0, (I, E)).astype(np.float32)
    plates = rng.integers(-1, 3, (I, V))
    bg = build_blocked(GraphTemplate(num_vertices=V, src=src, dst=dst),
                       rng.integers(0, P, V), block_size=B)
    return bg, src, dst, w, plates


def _session(**kw):
    bg, src, dst, w, plates = _arrays()
    return GopherSession.from_blocked(
        bg, weights={"latency": w}, vertex_attrs={"plate": plates},
        src=src, dst=dst, **kw)


@pytest.fixture(scope="module")
def ref_session():
    """One plain session for reference runs (results are deterministic,
    so caching state is irrelevant to the parity assertions)."""
    return _session()


@pytest.fixture()
def service():
    svc = GopherService(session=_session())
    yield svc.start()
    svc.stop()


def _assert_same_output(ref, got, label=""):
    assert set(ref.output) == set(got.output), label
    for k in ref.output:
        a, b = ref.output[k], got.output[k]
        if isinstance(a, np.ndarray):
            assert np.array_equal(a, b), (label, k)
        else:
            assert a == b, (label, k)


# --------------------------------------------------------------------------
# batching invisibility
# --------------------------------------------------------------------------

def test_batched_sssp_bitwise_matches_singles(service, ref_session):
    sources = [0, 7, 13, 42]
    refs = [ref_session.run(ref_session.plan("sssp", source=s))
            for s in sources]
    outs = service.query_many([("sssp", {"source": s}) for s in sources])
    for s, r, o in zip(sources, refs, outs):
        assert np.array_equal(r.output["final"], o.output["final"]), s
    # all four rode ONE admission -> one merged multi-source plan
    assert service.report()["widest_batch"] == 4
    assert service.report()["batches"] == 1


def test_mixed_analytic_batch_matches_singles(service, ref_session):
    reqs = [("nhop", {"source": 3, "n_hops": 2}),
            ("sssp", {"source": 9}),
            ("nhop", {"source": 9, "n_hops": 2}),
            ("tracking", {"plate": 1, "initial_vertex": 0})]
    outs = service.query_many(reqs)
    for (name, params), got in zip(reqs, outs):
        ref = ref_session.run(ref_session.plan(name, **params))
        _assert_same_output(ref, got, label=name)


def test_mismatched_params_not_merged(service, ref_session):
    """Same analytic + same source axis but different other params must
    NOT coalesce (a merged plan would silently apply one request's params
    to the other)."""
    reqs = [("sssp", {"source": 5, "max_supersteps": 64}),
            ("sssp", {"source": 5, "max_supersteps": 3})]
    outs = service.query_many(reqs)
    for (name, params), got in zip(reqs, outs):
        ref = ref_session.run(ref_session.plan(name, **params))
        _assert_same_output(ref, got, label=str(params))


def test_sequence_source_request_passes_through(service, ref_session):
    """A request that already carries a sequence source is planned as-is
    (its result keeps the (Q, V) leading axis)."""
    ref = ref_session.run(ref_session.plan("sssp", source=[2, 4]))
    got = service.query("sssp", source=[2, 4])
    assert got.output["final"].shape[0] == 2
    assert np.array_equal(ref.output["final"], got.output["final"])


# --------------------------------------------------------------------------
# warm staging cache
# --------------------------------------------------------------------------

def test_repeat_query_restages_nothing(service):
    service.query("sssp", source=1)
    service.query("sssp", source=2)  # same staged batch, different seed
    rep = service.session.last_run_report
    assert rep["staged_bytes"] == 0
    assert rep["staging_passes"] == 0
    assert rep["cache_hits"] >= 1
    stats = service.session.staging_cache_stats()
    assert stats is not None and stats["resident_bytes"] > 0


def test_prestage_moves_staging_ahead_of_first_query(service):
    service.prestage("sssp", source=0)
    service.query("sssp", source=0)
    rep = service.session.last_run_report
    assert rep["staged_bytes"] == 0 and rep["staging_passes"] == 0


def test_plain_session_is_promoted_to_warm():
    sess = GopherSession.from_blocked(
        _arrays()[0], weights={"latency": _arrays()[3]})
    assert sess._staging_cache is None
    svc = GopherService(session=sess)
    assert sess._staging_cache is not None
    assert sess._staging_cache.byte_budget is not None


# --------------------------------------------------------------------------
# admission / continuous batching
# --------------------------------------------------------------------------

def test_submit_many_forms_one_admission(service):
    tickets = service.submit_many(
        [("sssp", {"source": s}) for s in range(5)])
    for t in tickets:
        t.wait(timeout=120)
    rep = service.report()
    assert rep["batches"] == 1 and rep["widest_batch"] == 5


def test_concurrent_submitters_each_get_their_answer(service, ref_session):
    refs = {s: ref_session.run(ref_session.plan("sssp", source=s))
            .output["final"] for s in range(6)}
    errors = []

    def client(s):
        try:
            out = service.query("sssp", source=s, timeout=120)
            assert np.array_equal(out.output["final"], refs[s]), s
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append((s, e))

    threads = [threading.Thread(target=client, args=(s,)) for s in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=180)
        assert not t.is_alive(), "client thread hung"
    assert not errors, errors
    rep = service.report()
    assert rep["served"] >= 6
    assert rep["p50_ms"] is not None and rep["p95_ms"] >= rep["p50_ms"]


def test_stop_drains_queued_requests():
    svc = GopherService(session=_session()).start()
    tickets = svc.submit_many([("sssp", {"source": s}) for s in range(3)])
    svc.stop()  # graceful: everything already queued is served
    for t in tickets:
        assert t.done and t.result is not None
        assert t.latency_s is not None and t.latency_s >= 0


# --------------------------------------------------------------------------
# request plumbing / errors
# --------------------------------------------------------------------------

def test_bad_requests_raise_on_caller_thread(service):
    with pytest.raises(KeyError, match="unknown analytic"):
        service.submit("ssssp", source=0)
    with pytest.raises(TypeError, match="unknown parameter"):
        service.submit("sssp", sourcee=0)
    with pytest.raises(TypeError, match="missing required"):
        service.submit("sssp")
    with pytest.raises(TypeError, match="unknown plan knob"):
        service.submit("sssp", source=0, plan_kw={"laoyut": "dense"})


def test_engine_failure_delivered_and_loop_survives(service):
    with pytest.raises(Exception):
        service.query("sssp", source=10 ** 9, timeout=120)  # out of range
    # the serve loop must still be alive and serving
    out = service.query("sssp", source=0, timeout=120)
    assert np.isfinite(out.output["final"][0])
