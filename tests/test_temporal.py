"""Temporal parallelism: instances over `data` x partitions over `model`
(paper §IV-B independent/eventually patterns on the mesh) must match the
per-instance oracle and the serial blocked engine.  Subprocess with 8
forced host devices."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.configs.base import GraphConfig
from repro.core.generator import generate_collection
from repro.core.partition import partition_graph
from repro.core.blocked import build_blocked
from repro.core.temporal import pagerank_temporal
from repro.core.algorithms import pagerank

cfg = GraphConfig(name="t", num_vertices=500, avg_degree=3.0, num_instances=4,
                  num_partitions=4, block_size=32, seed=7)
tsg = generate_collection(cfg)
tmpl = tsg.template
assign = partition_graph(tmpl, 4, seed=7)
bg = build_blocked(tmpl, assign, 32)
active = np.stack([tsg.edge_values(t, "active") for t in range(4)])
mesh = jax.make_mesh((2, 4), ("data", "model"))
ranks, merged = pagerank_temporal(bg, tmpl.src, active, mesh,
                                  num_vertices=tmpl.num_vertices, iters=12)
oracles = np.stack([
    pagerank.oracle(tmpl.src, tmpl.dst, active[t], tmpl.num_vertices, iters=12)
    for t in range(4)
])
for t in range(4):
    err = np.abs(ranks[t] - oracles[t]).max() / oracles[t].max()
    assert err < 1e-4, (t, err)
err_m = np.abs(merged - oracles.mean(0)).max() / oracles.mean(0).max()
assert err_m < 1e-4, err_m
# serial blocked engine agreement
serial, _ = pagerank.run_blocked(bg, tmpl.src, active,
                                 num_vertices=tmpl.num_vertices, iters=12)
assert np.abs(serial - ranks).max() < 1e-6
print("TEMPORAL OK")
"""


@pytest.mark.slow
def test_temporal_pagerank_matches_oracle():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "TEMPORAL OK" in r.stdout
