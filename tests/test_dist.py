"""Distributed-path numerics: the shard_map implementations (vocab-parallel
embed/loss, expert-parallel MoE, full train step) must match the
single-device oracle.  Runs in a SUBPROCESS with 8 forced host devices so
the main test session keeps seeing one device.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp
assert len(jax.devices()) == 8

from repro.configs import get_config
from repro.dist.sharding import CPU_RUNTIME, Runtime, default_rules, shardings_for_schema
from repro.models import forward_train, init_model_params, model_schema
from repro.models.moe import moe_apply_ep, moe_apply_local, moe_schema
from repro.models.layers import init_params
from repro.train.data import SyntheticLMDataset

mesh = jax.make_mesh((2, 4), ("data", "model"))
rt = Runtime(mesh=mesh, dp_axes=("data",), tp_axis="model")

# ---- full train forward: dense (vocab-parallel loss + embed + SP) --------
cfg = get_config("glm4-9b").reduced().with_overrides(dtype="float32")
params = init_model_params(jax.random.key(0), cfg)
data = SyntheticLMDataset(cfg.vocab_size, 32, 4, seed=0)
batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

loss_cpu, _ = jax.jit(lambda p, b: forward_train(p, b, cfg, CPU_RUNTIME))(params, batch)
with mesh:
    p_sh = shardings_for_schema(model_schema(cfg), default_rules(), mesh)
    params_d = jax.device_put(params, p_sh)
    loss_dist, _ = jax.jit(lambda p, b: forward_train(p, b, cfg, rt))(params_d, batch)
err = abs(float(loss_cpu) - float(loss_dist))
print("dense loss cpu=%.6f dist=%.6f err=%.2e" % (loss_cpu, loss_dist, err))
assert err < 2e-4, err

# gradient parity
g_cpu = jax.grad(lambda p: forward_train(p, batch, cfg, CPU_RUNTIME)[0])(params)
with mesh:
    g_dist = jax.jit(jax.grad(lambda p: forward_train(p, batch, cfg, rt)[0]))(params_d)
gn = float(jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(g_cpu))))
dn = float(jnp.sqrt(sum(jnp.sum(jnp.square(a - b)) for a, b in
                        zip(jax.tree.leaves(g_cpu), jax.tree.leaves(g_dist)))))
print("dense grad rel err %.2e" % (dn / gn))
assert dn / gn < 1e-3, (dn, gn)

# ---- expert-parallel MoE vs local ------------------------------------------
mcfg = get_config("dbrx-132b").reduced().with_overrides(dtype="float32")
msch = moe_schema(mcfg)
mp = init_params(jax.random.key(1), msch)
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16, mcfg.d_model)),
                jnp.float32)
y_local, aux_local = moe_apply_local(mp, x, mcfg)
with mesh:
    specs = shardings_for_schema(msch, default_rules(), mesh)
    mp_d = jax.device_put(mp, specs)
    y_ep, aux_ep = jax.jit(
        lambda p, xx: moe_apply_ep(p, xx, mcfg, mesh, dp_axes=("data",),
                                   tp_axis="model")
    )(mp_d, x)
err = float(jnp.max(jnp.abs(y_local - y_ep)))
print("moe ep vs local: %.2e  aux %.4f vs %.4f" % (err, aux_local, aux_ep))
assert err < 1e-4, err
assert abs(float(aux_local) - float(aux_ep)) < 1e-4

# ---- TP flash decoding == single-device decode ----------------------------
import dataclasses
from repro.models import decode_step, init_serve_cache, prefill

rt_fd = dataclasses.replace(rt, flash_decode=True)
B, S = 2, 8
toks = jnp.asarray(np.random.default_rng(3).integers(0, cfg.vocab_size, (B, S)),
                   jnp.int32)

def serve(runtime):
    cache = init_serve_cache(cfg, B, S + 8, dtype=jnp.float32)
    _, cache = prefill(params, {"tokens": toks, "cache": cache}, cfg, runtime)
    d = {"tokens": jnp.zeros((B, 1), jnp.int32),
         "pos": jnp.full((B,), S, jnp.int32), "cache": cache}
    l2, _ = decode_step(params, d, cfg, runtime)
    return np.asarray(l2, np.float32)

l_cpu = serve(CPU_RUNTIME)
with mesh:
    l_tp = serve(rt_fd)
err = np.abs(l_cpu - l_tp).max()
print("flash_decode_tp err: %.2e" % err)
assert err < 1e-3, err

# ---- bf16-before-gather: loss parity within bf16 tolerance ----------------
from repro.train.train_step import make_train_step
from repro.train.optimizer import OptConfig, init_opt_state

cfg_bf = get_config("glm4-9b").reduced()  # bf16 compute dtype
params_bf = init_model_params(jax.random.key(0), cfg_bf)
oc = OptConfig(lr=1e-3)
with mesh:
    p_sh2 = shardings_for_schema(model_schema(cfg_bf), default_rules(), mesh)
    pd = jax.device_put(params_bf, p_sh2)
    s0 = init_opt_state(pd, oc)
    base = jax.jit(make_train_step(cfg_bf, rt, oc))
    opt = jax.jit(make_train_step(cfg_bf, rt, oc, cast_params_once=True))
    _, _, m_base = base(pd, s0, batch)
    pd2 = jax.device_put(params_bf, p_sh2)
    s02 = init_opt_state(pd2, oc)
    _, _, m_opt = opt(pd2, s02, batch)
d = abs(float(m_base["loss"]) - float(m_opt["loss"]))
print("cast_params loss delta: %.4f (base %.4f)" % (d, float(m_base["loss"])))
assert d < 0.02, d
print("DIST OK")
"""


@pytest.mark.slow
def test_distributed_matches_single_device():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=900, cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "DIST OK" in r.stdout
