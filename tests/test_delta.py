"""Delta-encoded temporal tiles + warm-started incremental recompute.

The deploy-time delta chain (``delta_<attr>.npz``: deduplicated payload
pools + per-instance tile references) must reconstruct batches
bitwise-identical to the full sparse fill while moving only each unique
tile's bytes from the store, and fall back to the full value slices the
moment the chain is stale or corrupt.  Warm-started fixpoints must
converge to the bitwise-identical state as cold starts on
monotone-improving collections, across every iBSP pattern and placement.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import GraphConfig
from repro.core.blocked import build_blocked
from repro.core.engine import (
    TemporalEngine, min_plus_program, pagerank_program, source_init,
)
from repro.core.generator import generate_collection
from repro.core.graph import TimeSeriesGraph
from repro.core.partition import partition_graph
from repro.core.semiring import INF
from repro.gofs import deploy_collection
from repro.gofs.layout import delta_slice_name
from repro.gofs.slices import read_array_slice, write_array_slice
from repro.gofs.store import GoFSStore
from repro.gopher import GopherSession

CFG = GraphConfig(
    name="delta", num_vertices=300, avg_degree=3.0, num_instances=6,
    num_partitions=3, block_size=32, instances_per_slice=2,
    bins_per_partition=2, cache_slots=4, seed=11,
)


def _slowly_varying(monotone: bool = True) -> TimeSeriesGraph:
    """Sparse, localized edge support with slowly tightening weights:
    most tiles are bitwise-unchanged between consecutive instances, and
    (when ``monotone``) no weight ever increases."""
    col = generate_collection(CFG, num_plates=6)
    I = len(col)
    src = np.asarray(col.template.src)
    dst = np.asarray(col.template.dst)
    rng = np.random.default_rng(0)
    live = (src < 40) & (dst < 40)  # localized: few active tiles
    w = np.where(live, np.asarray(col.edge_values(0, "latency"), np.float32),
                 np.float32(INF)).astype(np.float32)
    ws = [w]
    idx = np.nonzero(live)[0]
    for t in range(1, I):
        w = ws[-1].copy()
        band = rng.choice(idx, size=max(1, len(idx) // 8), replace=False)
        w[band] = (w[band] * 0.7).astype(np.float32)
        if not monotone and t == 2:
            w[idx[0]] = np.float32(ws[-1][idx[0]] * 3.0)  # one regression
        ws.append(w)
    insts = []
    for t in range(I):
        gi = col.instances[t]
        ev = dict(gi.edge_values)
        ev["latency"] = ws[t]
        insts.append(dataclasses.replace(gi, edge_values=ev))
    return TimeSeriesGraph(template=col.template, instances=insts)


@pytest.fixture(scope="module")
def env(tmp_path_factory):
    col = _slowly_varying()
    root = str(tmp_path_factory.mktemp("delta_gofs"))
    deploy_collection(col, CFG, root, sparse_absent={"latency": INF})
    tmpl = col.template
    assign = partition_graph(tmpl, CFG.num_partitions, seed=CFG.seed)
    bg = build_blocked(tmpl, assign, CFG.block_size)
    I = len(col)
    weights = np.stack([col.edge_values(t, "latency")
                        for t in range(I)]).astype(np.float32)
    return col, root, bg, weights


def _store(root, **kw):
    kw.setdefault("cache_slots", CFG.cache_slots)
    return GoFSStore(root, **kw)


# ------------------------------------------------------------ deploy stats
def test_deploy_records_delta_chain(env):
    col, root, bg, weights = env
    assert os.path.exists(os.path.join(
        root, delta_slice_name("latency") + ".npz"))
    ratio, monotone = _store(root).delta_stats("latency", zero=INF)
    assert ratio is not None and 0.0 < ratio < 1.0  # real temporal reuse
    assert monotone is True
    # stats recorded against a different absent value don't apply
    assert _store(root).delta_stats("latency", zero=0.0) == (None, None)


def test_non_monotone_collection_recorded(tmp_path):
    col = _slowly_varying(monotone=False)
    root = str(tmp_path / "gofs")
    deploy_collection(col, CFG, root, sparse_absent={"latency": INF})
    ratio, monotone = _store(root).delta_stats("latency", zero=INF)
    assert ratio is not None and monotone is False


# ---------------------------------------------------------- load roundtrip
def test_delta_load_bitwise_matches_full(env):
    col, root, bg, weights = env
    full = _store(root).load_blocked(bg, "latency", zero=INF,
                                     layout="sparse", delta=False)
    dlt = _store(root).load_blocked(bg, "latency", zero=INF,
                                    layout="sparse", delta=True)
    for f in ("tiles", "btiles", "rows", "cols", "brows", "bcols",
              "nnz", "bnnz"):
        assert np.array_equal(getattr(full, f), getattr(dlt, f)), f
    assert full.source_bytes is None  # full fill: nothing deduped
    assert dlt.source_bytes is not None
    assert dlt.source_bytes < dlt.staged_bytes()  # the dedupe paid off


def test_delta_stream_bitwise_matches_full(env):
    col, root, bg, weights = env
    full = _store(root).load_blocked(bg, "latency", zero=INF,
                                     layout="sparse", delta=False)
    pf = _store(root).load_blocked_stream(bg, "latency", zero=INF,
                                          layout="sparse", delta=True,
                                          chunk_instances=2)
    tiles, btiles, rows, src_bytes = [], [], [], 0
    with pf:
        for ch in pf:
            assert ch.staged_bytes is not None  # delta chunks report dedup
            src_bytes += ch.staged_bytes
            tiles.append(ch.tiles)
            btiles.append(ch.btiles)
            rows.append(ch.rows)
    assert np.array_equal(np.concatenate(tiles), np.asarray(full.tiles))
    assert np.array_equal(np.concatenate(btiles), np.asarray(full.btiles))
    assert np.array_equal(np.concatenate(rows), np.asarray(full.rows))
    assert src_bytes < full.staged_bytes()


def test_delta_survives_c0_cache(env):
    col, root, bg, weights = env
    store = _store(root, cache_slots=0)  # c0 disables value caching
    full = store.load_blocked(bg, "latency", zero=INF, layout="sparse",
                              delta=False)
    dlt = store.load_blocked(bg, "latency", zero=INF, layout="sparse")
    assert np.array_equal(np.asarray(full.tiles), np.asarray(dlt.tiles))
    assert dlt.source_bytes is not None  # chain pinned past slots=0
    assert store.cache.stats()["pinned"] >= 2  # tile map + delta pool


# ------------------------------------------------------------- fallbacks
def _corrupt(root, **overrides):
    """Rewrite the delta slice with mutated arrays; returns a fresh store
    (the original's pinned pool would mask the rewrite)."""
    path = os.path.join(root, delta_slice_name("latency"))
    arrs = read_array_slice(path)
    arrs.update(overrides)
    write_array_slice(path, arrs)


@pytest.mark.parametrize("mutation", ["refs_out_of_range", "wrong_block",
                                      "truncated_pool", "missing_file"])
def test_stale_or_corrupt_delta_falls_back_to_full(env, tmp_path, mutation):
    col, root, bg, weights = env
    # private deployment copy: mutations must not leak into other tests
    droot = str(tmp_path / "gofs")
    deploy_collection(col, CFG, droot, sparse_absent={"latency": INF})
    path = os.path.join(droot, delta_slice_name("latency") + ".npz")
    if mutation == "refs_out_of_range":
        arrs = read_array_slice(path)
        bad = arrs["ref_local"].copy()
        bad[bad >= 0] = 10 ** 6  # points past the payload pool
        _corrupt(droot, ref_local=bad)
    elif mutation == "wrong_block":
        _corrupt(droot, block_size=np.asarray(CFG.block_size * 2))
    elif mutation == "truncated_pool":
        arrs = read_array_slice(path)
        _corrupt(droot, payloads_local=arrs["payloads_local"][:1])
    else:
        os.remove(path)
    store = _store(droot)
    out = store.load_blocked(bg, "latency", zero=INF, layout="sparse",
                             delta=True)
    ref = _store(root).load_blocked(bg, "latency", zero=INF,
                                    layout="sparse", delta=False)
    assert np.array_equal(np.asarray(out.tiles), np.asarray(ref.tiles))
    assert out.source_bytes is None  # fell back to the full fill
    # the stream falls back too, to plain read+fill chunks
    with store.load_blocked_stream(bg, "latency", zero=INF,
                                   layout="sparse", delta=True,
                                   chunk_instances=2) as pf:
        got = np.concatenate([ch.tiles for ch in pf])
    assert np.array_equal(got, np.asarray(ref.tiles))


def test_delta_chain_rejects_foreign_blocking(env):
    col, root, bg, weights = env
    # same collection re-blocked differently: recorded chain must refuse
    assign = partition_graph(col.template, 2, seed=99)
    bg2 = build_blocked(col.template, assign, CFG.block_size)
    out = _store(root).load_blocked(bg2, "latency", zero=INF,
                                    layout="sparse", delta=True)
    ref = bg2.stage_sparse(weights, zero=INF)
    assert np.array_equal(np.asarray(out.tiles), np.asarray(ref.tiles))
    assert out.source_bytes is None


# ----------------------------------------------------- appended-data faults
@pytest.fixture()
def grown_env(tmp_path):
    """A prefix deployment grown by one append (manifest version 1) —
    the surface the corruption tests below damage."""
    from repro.gofs import append_instances

    col = _slowly_varying()
    root = str(tmp_path / "gofs")
    deploy_collection(
        TimeSeriesGraph(template=col.template, instances=col.instances[:3]),
        CFG, root, sparse_absent={"latency": INF})
    append_instances(
        TimeSeriesGraph(template=col.template, instances=col.instances[3:]),
        root)
    assign = partition_graph(col.template, CFG.num_partitions, seed=CFG.seed)
    bg = build_blocked(col.template, assign, CFG.block_size)
    return col, root, bg


def _full_ref(col, bg):
    w = np.stack([col.edge_values(t, "latency")
                  for t in range(len(col))]).astype(np.float32)
    return bg.stage_sparse(w, zero=INF)


def test_appended_delta_chain_serves(grown_env):
    """Baseline for this section: the grown deployment's extended chain
    reconstructs the full history bitwise and still dedupes."""
    col, root, bg = grown_env
    store = _store(root)
    assert store.version == 1
    out = store.load_blocked(bg, "latency", zero=INF, layout="sparse",
                             delta=True)
    ref = _full_ref(col, bg)
    assert np.array_equal(np.asarray(out.tiles), np.asarray(ref.tiles))
    assert np.array_equal(np.asarray(out.btiles), np.asarray(ref.btiles))
    assert out.source_bytes is not None  # chain used, not the fallback
    ratio, monotone = store.delta_stats("latency", zero=INF)
    assert ratio is not None and 0.0 < ratio < 1.0


@pytest.mark.parametrize("which", ["delta", "tilemap"])
def test_truncated_appended_slice_falls_back(grown_env, which):
    """A pack torn after the append (half its bytes) must degrade to the
    full value-slice fill, bitwise identical — never crash."""
    from repro.gofs.layout import tile_map_name

    col, root, bg = grown_env
    name = delta_slice_name("latency") if which == "delta" \
        else tile_map_name("latency")
    p = os.path.join(root, name + ".npz")
    with open(p, "rb") as f:
        data = f.read()
    with open(p, "wb") as f:
        f.write(data[: len(data) // 2])
    store = _store(root)
    if which == "tilemap":
        # activity becomes unknown (None), never an exception
        assert store.tile_occupancy(bg, "latency", zero=INF) is None
        assert store.sparse_buckets(bg, "latency", zero=INF) is None
    out = store.load_blocked(bg, "latency", zero=INF, layout="sparse",
                             delta=True)
    ref = _full_ref(col, bg)
    assert np.array_equal(np.asarray(out.tiles), np.asarray(ref.tiles))
    if which == "delta":
        assert out.source_bytes is None  # fell back to the full fill


def test_appended_pool_fingerprint_mismatch_falls_back(grown_env):
    """A delta pool whose recorded blocked-structure fingerprint no longer
    matches the reader's (e.g. a bad append against a re-blocked
    collection) is rejected, not dereferenced."""
    col, root, bg = grown_env
    path = os.path.join(root, delta_slice_name("latency"))
    arrs = read_array_slice(path)
    bad = arrs["tiles_rc"].copy()
    bad[0] ^= 1  # one flipped tile coordinate
    write_array_slice(path, {**arrs, "tiles_rc": bad})
    store = _store(root)
    out = store.load_blocked(bg, "latency", zero=INF, layout="sparse",
                             delta=True)
    ref = _full_ref(col, bg)
    assert np.array_equal(np.asarray(out.tiles), np.asarray(ref.tiles))
    assert out.source_bytes is None


def test_manifest_delta_version_skew_falls_back(grown_env):
    """Manifest says 6 instances but the delta chain still records the
    pre-append 3 (a reader racing a partially propagated append): the
    chain must be treated as stale for the visible range."""
    col, root, bg = grown_env
    path = os.path.join(root, delta_slice_name("latency"))
    arrs = read_array_slice(path)
    write_array_slice(path, {
        **arrs,
        "n_instances": np.asarray(3),
        "ref_local": arrs["ref_local"][:3],
        "ref_boundary": arrs["ref_boundary"][:3],
    })
    store = _store(root)
    assert store.num_timesteps() == len(col)  # manifest governs visibility
    out = store.load_blocked(bg, "latency", zero=INF, layout="sparse",
                             delta=True)
    ref = _full_ref(col, bg)
    assert np.array_equal(np.asarray(out.tiles), np.asarray(ref.tiles))
    assert out.source_bytes is None


def test_corrupt_manifest_refresh_keeps_serving(grown_env):
    """A torn ``collection.json`` (mid-append crash before the atomic
    replace existed) must not take down an open reader: ``refresh``
    reports no change and the bound version keeps serving."""
    col, root, bg = grown_env
    store = _store(root)
    before = store.load_blocked(bg, "latency", zero=INF, layout="sparse",
                                delta=True)
    man = os.path.join(root, "collection.json")
    with open(man) as f:
        text = f.read()
    with open(man, "w") as f:
        f.write(text[: len(text) // 2])
    assert store.refresh() is False  # unreadable manifest: no rebind
    after = store.load_blocked(bg, "latency", zero=INF, layout="sparse",
                               delta=True)
    assert np.array_equal(np.asarray(before.tiles), np.asarray(after.tiles))
    with open(man, "w") as f:
        f.write(text)  # restored: refresh sees the same version again
    assert store.refresh() is False


# ------------------------------------------------------------- warm start
@pytest.mark.parametrize("pattern", ["sequential", "independent",
                                     "eventually"])
def test_warm_start_bitwise_parity(env, pattern):
    col, root, bg, weights = env
    prog = min_plus_program("sssp", init=source_init(0))
    eng = TemporalEngine(bg)
    merge = "mean" if pattern == "eventually" else None
    cold = eng.run(prog, weights, pattern=pattern, merge=merge)
    warm = eng.run(prog, weights, pattern=pattern, merge=merge,
                   warm_start=True)
    assert np.array_equal(cold.values, warm.values)
    if merge:
        assert np.array_equal(cold.merged, warm.merged)
    assert warm.warm_start and not cold.warm_start
    saved = warm.supersteps_saved()
    assert saved is not None and saved.shape == (len(col),)
    assert (saved >= 0).all() and saved[0] == 0  # instance 0 is cold
    assert cold.supersteps_saved() is None


def test_warm_start_streamed_parity(env):
    col, root, bg, weights = env
    prog = min_plus_program("sssp", init=source_init(0))
    eng = TemporalEngine(bg)
    cold = eng.run(prog, weights, pattern="independent")
    warm = eng.run(prog, weights, pattern="independent", warm_start=True,
                   staging="async")
    assert np.array_equal(cold.values, warm.values)


def test_warm_start_iterate_falls_back_cold(env):
    col, root, bg, weights = env
    from repro.core.algorithms import pagerank

    tmpl = col.template
    active = np.isfinite(weights).astype(np.float32)
    pw = pagerank.edge_weights_for_instances(tmpl.src, active,
                                             tmpl.num_vertices)
    prog = pagerank_program(tmpl.num_vertices, iters=6)
    eng = TemporalEngine(bg)
    cold = eng.run(prog, pw, pattern="independent")
    warm = eng.run(prog, pw, pattern="independent", warm_start=True)
    assert np.array_equal(cold.values, warm.values)
    assert not warm.warm_start  # fixed-iterate: warm seed would change it


# ----------------------------------------------------- planner + session
def test_planner_auto_selects_delta_and_warm(env):
    col, root, bg, weights = env
    sess = GopherSession(_store(root))
    plan = sess.plan("sssp", source=0, pattern="independent")
    assert plan.layout.value == "sparse"
    assert plan.delta.value is True and plan.delta.source == "auto"
    assert plan.warm.value is True and plan.warm.source == "auto"
    text = plan.explain()
    assert "delta" in text and "warm" in text
    assert plan.estimate_dict["source_bytes_delta"] is not None
    # overrides stick and are recorded
    p2 = sess.plan("sssp", source=0, delta=False, warm=False)
    assert p2.delta.value is False and p2.delta.source == "override"
    assert p2.warm.value is False and p2.warm.source == "override"


def test_planner_warm_off_for_non_monotone(tmp_path):
    col = _slowly_varying(monotone=False)
    root = str(tmp_path / "gofs")
    deploy_collection(col, CFG, root, sparse_absent={"latency": INF})
    plan = GopherSession(_store(root)).plan("sssp", source=0)
    assert plan.delta.value is True  # redundancy is still real
    assert plan.warm.value is False  # a weight increased somewhere


def test_planner_warm_off_for_plus_mul(env):
    col, root, bg, weights = env
    plan = GopherSession(_store(root)).plan("pagerank")
    assert plan.warm.value is False  # zero_fill=0.0 is not min-plus


def test_session_delta_warm_end_to_end(env):
    col, root, bg, weights = env
    sess = GopherSession(_store(root))
    auto = sess.run(sess.plan("sssp", source=0, pattern="independent"))
    rep = dict(sess.last_run_report)
    sess2 = GopherSession(_store(root))
    ref = sess2.run(sess2.plan("sssp", source=0, pattern="independent",
                               delta=False, warm=False))
    rep2 = dict(sess2.last_run_report)
    assert np.array_equal(auto.engine.values, ref.engine.values)
    assert auto.engine.warm_start and not ref.engine.warm_start
    # staged-bytes accounting reflects the dedup, not the reconstruction
    assert rep["staged_bytes"] < rep2["staged_bytes"]


def test_rowwise_transform_streams_async(env):
    col, root, bg, weights = env
    sess = GopherSession(_store(root))
    plan = sess.plan("pagerank")
    assert plan.staging.value == "async"  # rowwise transform streams
    got = sess.run(plan)
    assert sess.last_run_report["staging_passes"] == 1
    sess2 = GopherSession(_store(root))
    ref = sess2.run(sess2.plan("pagerank", staging="sync"))
    assert np.array_equal(got.output["ranks"], ref.output["ranks"])


# ------------------------------------------------------------- mesh warm
MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from tests.test_delta import CFG, _slowly_varying
from repro.core.blocked import build_blocked
from repro.core.engine import TemporalEngine, min_plus_program, source_init
from repro.core.partition import partition_graph

col = _slowly_varying()
tmpl = col.template
# 4 partitions: the model mesh axis must divide the partition count
assign = partition_graph(tmpl, 4, seed=CFG.seed)
bg = build_blocked(tmpl, assign, CFG.block_size)
I = len(col)
w = np.stack([col.edge_values(t, "latency")
              for t in range(I)]).astype(np.float32)
mesh = jax.make_mesh((2, 4), ("data", "model"))
prog = min_plus_program("sssp", init=source_init(0))
eng_m = TemporalEngine(bg, mesh=mesh, model_axes=("model",))
eng_s = TemporalEngine(bg)
for pattern in ("sequential", "independent", "eventually"):
    merge = "mean" if pattern == "eventually" else None
    cold = eng_s.run(prog, w, pattern=pattern, merge=merge)
    warm = eng_m.run(prog, w, pattern=pattern, merge=merge,
                     warm_start=True)
    assert np.array_equal(cold.values, warm.values), pattern
    if merge:
        np.testing.assert_allclose(cold.merged, warm.merged, rtol=1e-6)
print("WARM MESH OK")
"""


@pytest.mark.slow
def test_warm_start_mesh_parity():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", MESH_SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "WARM MESH OK" in r.stdout
