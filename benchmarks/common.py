"""Shared benchmark scaffolding: the scaled-down TR analogue + deployments.

The paper's TR collection (19.4M vertices, 146 instances, 12 hosts) is
scaled to a CPU-runnable replica that preserves the *relative* layout
questions: temporal packing (paper i1/i20 -> i1/i6 here), subgraph bin
packing (s20/s40 -> s4/s8), slice caching (c0/c14).  Benchmarks print
``name,us_per_call,derived`` CSV rows (derived = quantities computed from
the measurement, e.g. slice counts).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, Tuple

from repro.configs.base import GraphConfig
from repro.core.generator import generate_collection
from repro.gofs import GoFSStore, deploy_collection

BENCH_GRAPH = GraphConfig(
    name="tr-bench",
    num_vertices=4096,
    avg_degree=2.0,
    num_instances=12,
    num_partitions=4,
    block_size=64,
    instances_per_slice=6,
    bins_per_partition=4,
    cache_slots=14,
    seed=5,
)

# layout configurations mirroring the paper's §VI-B grid
LAYOUTS = {
    "s4-i1": dict(bins_per_partition=4, instances_per_slice=1),
    "s4-i6": dict(bins_per_partition=4, instances_per_slice=6),
    "s8-i1": dict(bins_per_partition=8, instances_per_slice=1),
    "s8-i6": dict(bins_per_partition=8, instances_per_slice=6),
}

_CACHE: Dict[str, Tuple[GraphConfig, str]] = {}


def deployments(root: str = "/tmp/gofs_bench"):
    """Deploy the bench collection under every layout config (once)."""
    if _CACHE:
        return _CACHE
    tsg = generate_collection(BENCH_GRAPH)
    for name, kw in LAYOUTS.items():
        cfg = dataclasses.replace(BENCH_GRAPH, **kw)
        d = os.path.join(root, name)
        if not os.path.exists(os.path.join(d, "collection.json")):
            deploy_collection(tsg, cfg, d)
        _CACHE[name] = (cfg, d)
    return _CACHE


def store_for(name: str, cache_slots: int, **kw) -> GoFSStore:
    deps = deployments()
    cfg, root = deps[name]
    return GoFSStore(root, cache_slots=cache_slots, **kw)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
