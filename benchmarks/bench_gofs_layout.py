"""Fig. 6 analogue: cumulative time to read ALL instances of EVERY subgraph,
per layout deployment (temporal packing x bin packing x caching).

The paper's plot sorts subgraphs largest-to-smallest and accumulates the
total read time; we report the totals, the crossover behaviour (packing
wins once small subgraphs dominate), and slice counts.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import LAYOUTS, deployments, emit, store_for


def scan_all(store) -> float:
    """Read every instance of every subgraph (bin-major order).  Returns
    per-subgraph total read seconds, ordered largest subgraph first."""
    sizes = []
    times = []
    for g in store.subgraph_ids():
        topo = store.get_topology(g)
        t0 = time.perf_counter()
        for t in range(store.num_timesteps()):
            store.get_instance(t, g)
        times.append(time.perf_counter() - t0)
        sizes.append(topo.num_vertices)
    order = np.argsort(-np.asarray(sizes))
    return np.asarray(times)[order]


def run() -> None:
    deployments()
    results = {}
    for name in LAYOUTS:
        for cache, slots in (("c14", 14), ("c0", 0)):
            if cache == "c0" and name != "s4-i6":
                continue  # paper shows one uncached line
            store = store_for(name, slots,
                              vertex_projection=("plate",),
                              edge_projection=("latency", "active"))
            store.reset_stats()
            t0 = time.perf_counter()
            per_sg = scan_all(store)
            wall = time.perf_counter() - t0
            stats = store.snapshot_stats()
            key = f"{name}-{cache}"
            results[key] = (per_sg, wall, stats)
            n_inst = store.num_timesteps() * len(store.subgraph_ids())
            emit(
                f"gofs_layout/{key}", wall / n_inst * 1e6,
                f"slices={int(stats['slices_read'])};"
                f"bytes={int(stats['bytes_read'])};"
                f"hit_rate={stats['hit_rate']:.3f};"
                f"cum_read_s={per_sg.sum():.4f}",
            )
    # packing benefit (paper: i20 beats i1 once modest subgraphs enter)
    if "s4-i6-c14" in results and "s4-i1-c14" in results:
        a = results["s4-i6-c14"][0].sum()
        b = results["s4-i1-c14"][0].sum()
        emit("gofs_layout/derived_packing_speedup", 0.0,
             f"i6_vs_i1_read_time_ratio={b / max(a, 1e-12):.2f}")
    if "s4-i6-c14" in results and "s8-i6-c14" in results:
        a = results["s4-i6-c14"][2]["slices_read"]
        b = results["s8-i6-c14"][2]["slices_read"]
        emit("gofs_layout/derived_binning_slices", 0.0,
             f"s8_vs_s4_slices_ratio={b / max(a, 1):.2f}")


if __name__ == "__main__":
    run()
