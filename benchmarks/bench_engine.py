"""Gopher engine comparison: subgraph-centric vs vertex-centric BSP.

Reproduces the paper's core claim (fewer supersteps => fewer barriers and
boundary exchanges) on the blocked engine, and reports the host engine's
message economy (messages ~ cut edges, not total edges).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import BENCH_GRAPH, emit
from repro.core.algorithms import sssp
from repro.core.blocked import build_blocked
from repro.core.generator import generate_collection
from repro.core.ibsp import InMemoryProvider
from repro.core.partition import discover_subgraphs, edge_cut, partition_graph
from repro.core.subgraph import build_subgraphs


def _road_grid(n: int):
    """n x n 4-neighbour road grid — the paper's motivating topology.
    High-diameter + low cut: the regime where subgraph-centric local
    convergence crushes vertex-centric superstep counts."""
    from repro.core.graph import GraphTemplate

    ids = np.arange(n * n).reshape(n, n)
    src = np.concatenate([ids[:, :-1].ravel(), ids[:-1, :].ravel(),
                          ids[:, 1:].ravel(), ids[1:, :].ravel()])
    dst = np.concatenate([ids[:, 1:].ravel(), ids[1:, :].ravel(),
                          ids[:, :-1].ravel(), ids[:-1, :].ravel()])
    return GraphTemplate(num_vertices=n * n, src=src.astype(np.int64),
                         dst=dst.astype(np.int64))


def run_road() -> None:
    n = 48
    tmpl = _road_grid(n)
    rng = np.random.default_rng(0)
    # quadrant partitioning (low cut, like a geographic road partition)
    q = (np.arange(n * n) // n >= n // 2) * 2 + (np.arange(n * n) % n >= n // 2)
    assign = q.astype(np.int32)
    bg = build_blocked(tmpl, assign, 64)
    w = rng.random((1, tmpl.num_edges)).astype(np.float32) + 0.1
    d_sg, st_sg = sssp.run_blocked(bg, w, 0, subgraph_centric=True,
                                   max_supersteps=512)
    d_vc, st_vc = sssp.run_blocked(bg, w, 0, subgraph_centric=False,
                                   max_supersteps=512)
    finite = np.isfinite(d_sg)
    assert np.allclose(d_vc[finite], d_sg[finite], rtol=1e-5)
    emit("engine/road_grid_superstep_ratio", 0.0,
         f"sg={int(st_sg['supersteps'][0])};vc={int(st_vc['supersteps'][0])};"
         f"cut={edge_cut(tmpl, assign)};edges={tmpl.num_edges};"
         f"vc_over_sg={st_vc['supersteps'][0] / max(int(st_sg['supersteps'][0]), 1):.1f}")


def run_straggler_balance() -> None:
    """Paper §V-D: bin packing subgraphs balances per-worker load (the BSP
    superstep is limited by its slowest worker).  Compare the load imbalance
    (max/mean vertices per bin) of greedy largest-first bin packing vs naive
    round-robin assignment."""
    from repro.core.partition import (bin_pack_subgraphs, discover_subgraphs,
                                      partition_graph)
    from repro.core.subgraph import build_subgraphs

    tsg = generate_collection(BENCH_GRAPH)
    tmpl = tsg.template
    assign = partition_graph(tmpl, BENCH_GRAPH.num_partitions,
                             seed=BENCH_GRAPH.seed)
    sg_ids = discover_subgraphs(tmpl, assign)
    subs = build_subgraphs(tmpl, assign, sg_ids)
    n_bins = 8
    ids = np.array(sorted(subs))
    sizes = np.array([subs[g].num_vertices for g in ids])
    packed = bin_pack_subgraphs(sizes, ids, n_bins)
    loads_packed = np.array([
        sizes[np.isin(ids, b)].sum() for b in packed
    ], np.float64)
    rr = [ids[i::n_bins] for i in range(n_bins)]
    loads_rr = np.array([sizes[np.isin(ids, b)].sum() for b in rr], np.float64)
    imb_p = loads_packed.max() / max(loads_packed.mean(), 1)
    imb_r = loads_rr.max() / max(loads_rr.mean(), 1)
    emit("engine/straggler_balance", 0.0,
         f"binpack_imbalance={imb_p:.3f};roundrobin_imbalance={imb_r:.3f};"
         f"improvement={imb_r / imb_p:.2f}x")
    assert imb_p <= imb_r + 1e-9


def run() -> None:
    run_road()
    run_straggler_balance()
    tsg = generate_collection(BENCH_GRAPH)
    tmpl = tsg.template
    assign = partition_graph(tmpl, BENCH_GRAPH.num_partitions,
                             seed=BENCH_GRAPH.seed)
    bg = build_blocked(tmpl, assign, BENCH_GRAPH.block_size)
    w = np.stack([tsg.edge_values(t, "latency") for t in range(4)])

    t0 = time.perf_counter()
    d_sg, st_sg = sssp.run_blocked(bg, w, 0, subgraph_centric=True)
    t_sg = time.perf_counter() - t0
    t0 = time.perf_counter()
    d_vc, st_vc = sssp.run_blocked(bg, w, 0, subgraph_centric=False,
                                   max_supersteps=512)
    t_vc = time.perf_counter() - t0
    finite = np.isfinite(d_sg)
    assert np.allclose(d_vc[finite], d_sg[finite], rtol=1e-5)

    ss_sg = int(st_sg["supersteps"].sum())
    ss_vc = int(st_vc["supersteps"].sum())
    emit("engine/subgraph_centric", t_sg / 4 * 1e6,
         f"supersteps={ss_sg};local_sweeps={int(st_sg['local_sweeps'].sum())}")
    emit("engine/vertex_centric", t_vc / 4 * 1e6,
         f"supersteps={ss_vc}")
    emit("engine/derived_superstep_ratio", 0.0,
         f"vc_over_sg={ss_vc / max(ss_sg, 1):.2f};"
         f"boundary_bytes_per_superstep={bg.num_boundary * 4}")

    # host engine message economy (paper: messages ~ cut edges)
    sg_ids = discover_subgraphs(tmpl, assign)
    subs = build_subgraphs(tmpl, assign, sg_ids)
    prov = InMemoryProvider(tsg, subs, vertex_attrs=(),
                            edge_attrs=("latency", "active"))
    _, res = sssp.run_host(prov, 0)
    cut = edge_cut(tmpl, assign)
    emit("engine/host_messages", 0.0,
         f"msgs={res.stats.superstep_messages};cut_edges={cut};"
         f"total_edges={tmpl.num_edges};"
         f"msgs_per_cut_edge_per_timestep="
         f"{res.stats.superstep_messages / max(cut, 1) / len(tsg):.2f}")


if __name__ == "__main__":
    run()
