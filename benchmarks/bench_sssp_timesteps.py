"""Fig. 7 analogue: iBSP temporal SSSP time per timestep iteration, for
three GoFS configurations (uncached, cached-unpacked, cached-packed).

Timestep 0 includes the template load, as in the paper; later timesteps
show the GoFS configuration deltas.  Also validates the result against the
numpy oracle each run (a benchmark that silently computes garbage is
worthless).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import BENCH_GRAPH, deployments, emit, store_for
from repro.core.algorithms import sssp
from repro.core.generator import generate_collection

# Cached configs use 16 slots = one slice per (partition x bin) for the one
# projected edge attribute — the analogue of the paper's c14 = "one slice
# per attribute" sizing rule (§V-E): benefits appear only when the cache
# fits the per-timestep working set.
CONFIGS = [
    ("s4-i6", 0),   # packed, no cache   (paper s20-i20-c0)
    ("s4-i1", 16),  # unpacked, cached   (paper s20-i1-c14)
    ("s4-i6", 16),  # packed, cached     (paper s20-i20-c14)
]

SOURCE = 0


def run() -> None:
    deployments()
    # oracle once
    tsg = generate_collection(BENCH_GRAPH)
    w = np.stack([tsg.edge_values(t, "latency") for t in range(len(tsg))])
    d_oracle = sssp.oracle(tsg.template.src, tsg.template.dst, w,
                           tsg.template.num_vertices, SOURCE)
    finite = np.isfinite(d_oracle)

    for name, slots in CONFIGS:
        store = store_for(name, slots, vertex_projection=(),
                          edge_projection=("latency",))
        store.reset_stats()
        per_t = []
        # per-timestep timing: drive timesteps one by one
        compute = sssp.make_compute(SOURCE)
        from repro.core.ibsp import _TimestepBSP

        t_start = time.perf_counter()
        for t in range(store.num_timesteps()):
            t0 = time.perf_counter()
            bsp = _TimestepBSP(store, t, compute, {}, [], None)
            bsp.run()
            per_t.append(time.perf_counter() - t0)
        wall = time.perf_counter() - t_start
        # validate
        d = np.full(tsg.template.num_vertices, np.inf)
        for g, dist in compute.result.items():
            d[store.get_topology(g).vertices] = dist
        ok = np.array_equal(np.isfinite(d), finite) and np.allclose(
            d[finite], d_oracle[finite], rtol=1e-6)
        key = f"{name}-c{slots}"
        emit(
            f"sssp_timesteps/{key}", wall / len(per_t) * 1e6,
            f"t0_s={per_t[0]:.4f};rest_mean_s={np.mean(per_t[1:]):.4f};"
            f"slices={int(store.stats.slices_read)};valid={ok}",
        )
        assert ok, f"SSSP result mismatch on {key}"


if __name__ == "__main__":
    run()
