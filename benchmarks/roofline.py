"""Roofline model: turn dry-run records into the three-term analysis.

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.  All inputs are PER-DEVICE quantities from the compiled SPMD module:

  T_compute    = FLOPs / PEAK_FLOPS
  T_memory     = bytes_accessed / HBM_BW
  T_collective = sum_kind bytes_kind * ring_factor(kind) / ICI_BW

Ring factors on a 16-ary mesh axis (k=16): all-gather and all-to-all move
(k-1)/k of the op's output bytes per link; all-reduce = reduce-scatter +
all-gather = ~2(k-1)/k; reduce-scatter outputs are post-division, so its
factor is (k-1); collective-permute is a single hop.  (The dry-run stores
aggregate bytes per kind; the per-axis refinement happens in the §Perf
hillclimb where it matters.)

Scan-body correction: cost_analysis counts while-loop bodies ONCE; the
two-point (L, L/2) fit recovers per-layer body cost + outside cost, so
``totals from fit`` = outside + per_layer * L.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

RING_FACTOR = {
    "all-gather": 15.0 / 16.0,
    "all-to-all": 15.0 / 16.0,
    "all-reduce": 2.0 * 15.0 / 16.0,
    "reduce-scatter": 15.0,
    "collective-permute": 1.0,
}


@dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    flops: float  # per device, scan-corrected
    bytes_hbm: float
    coll_bytes: Dict[str, float]
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float  # useful flops per device (6ND / 2ND)
    useful_ratio: float  # model_flops / flops
    roofline_fraction: float  # t_compute / max(all terms)
    mem_gb: float
    compile_s: float
    skipped: Optional[str] = None

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)


def _fit_totals(rec: Dict[str, Any]) -> Dict[str, Any]:
    """Scan-corrected totals: prefer the unrolled-units fit, else raw."""
    full = rec["full"]
    if "fit" not in rec:
        return {
            "flops": full["flops"],
            "bytes": full["bytes"],
            "collectives": dict(full["collectives"]),
        }
    fit = rec["fit"]
    coll = {k: max(v["total"], 0.0) for k, v in fit["collectives"].items()}
    return {
        "flops": max(fit["flops"]["total"], full["flops"]),
        "bytes": max(fit["bytes"]["total"], full["bytes"]),
        "collectives": coll,
    }


def model_flops_per_device(arch_cfg, shape, n_devices: int) -> float:
    """6·N·D (train) or 2·N_active·D (serve fwd), D = global tokens."""
    n = arch_cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / n_devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / n_devices
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch / n_devices


def analyze_record(rec: Dict[str, Any]) -> Optional[RooflineCell]:
    if "skipped" in rec:
        return RooflineCell(
            arch=rec["arch"], shape=rec["shape"], mesh=rec.get("mesh", ""),
            flops=0, bytes_hbm=0, coll_bytes={}, t_compute=0, t_memory=0,
            t_collective=0, dominant="-", model_flops=0, useful_ratio=0,
            roofline_fraction=0, mem_gb=0, compile_s=0,
            skipped=rec["skipped"],
        )
    if "error" in rec:
        return None
    from repro.configs import get_config, shape_by_name

    totals = _fit_totals(rec)
    t_comp = totals["flops"] / PEAK_FLOPS
    t_mem = totals["bytes"] / HBM_BW
    t_coll = sum(
        b * RING_FACTOR.get(k, 1.0) / ICI_BW
        for k, b in totals["collectives"].items()
    )
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    cfg = get_config(rec["arch"])
    shape = shape_by_name(rec["shape"])
    mf = model_flops_per_device(cfg, shape, rec["num_devices"])
    mem = rec["full"]["memory"]
    mem_gb = ((mem.get("argument_size_in_bytes") or 0)
              + (mem.get("temp_size_in_bytes") or 0)) / 1e9
    t_bound = max(t_comp, t_mem, t_coll)
    return RooflineCell(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        flops=totals["flops"], bytes_hbm=totals["bytes"],
        coll_bytes=totals["collectives"],
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        dominant=dominant,
        model_flops=mf,
        useful_ratio=mf / totals["flops"] if totals["flops"] else 0.0,
        roofline_fraction=(mf / PEAK_FLOPS) / t_bound if t_bound else 0.0,
        mem_gb=mem_gb,
        compile_s=rec["full"]["compile_seconds"],
    )


def load_cells(path: str) -> List[RooflineCell]:
    out = []
    for line in open(path):
        c = analyze_record(json.loads(line))
        if c is not None:
            out.append(c)
    return out


def markdown_table(cells: List[RooflineCell]) -> str:
    hdr = ("| arch | shape | T_comp (ms) | T_mem (ms) | T_coll (ms) | bound | "
           "useful FLOPs ratio | roofline frac | mem GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in cells:
        if c.skipped:
            rows.append(f"| {c.arch} | {c.shape} | — | — | — | skipped | — | — | — |")
            continue
        rows.append(
            f"| {c.arch} | {c.shape} | {c.t_compute*1e3:.2f} | "
            f"{c.t_memory*1e3:.2f} | {c.t_collective*1e3:.2f} | "
            f"{c.dominant} | {c.useful_ratio:.2f} | "
            f"{c.roofline_fraction:.3f} | {c.mem_gb:.1f} |"
        )
    return hdr + "\n".join(rows)
