"""Fig. 8 analogue: cumulative slices read from disk as the iBSP SSSP
timesteps progress, per GoFS configuration.

The paper's qualitative claims, asserted here:
  * no caching       -> highest slope (every access hits disk);
  * cached, unpacked -> fewer reads;
  * cached + packed  -> fewest (one slice covers several instances).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import deployments, emit, store_for
from repro.core.algorithms import sssp
from repro.core.ibsp import _TimestepBSP

# 16 slots = one slice per (partition x bin) for the projected attribute —
# the paper's c14 sizing rule (§V-E) applied to this deployment's shape.
CONFIGS = [
    ("s4-i6", 0),
    ("s4-i1", 16),
    ("s4-i6", 16),
]

SOURCE = 0


def run() -> None:
    deployments()
    curves = {}
    for name, slots in CONFIGS:
        store = store_for(name, slots, vertex_projection=(),
                          edge_projection=("latency",))
        store.reset_stats()
        compute = sssp.make_compute(SOURCE)
        cum = []
        for t in range(store.num_timesteps()):
            bsp = _TimestepBSP(store, t, compute, {}, [], None)
            bsp.run()
            cum.append(int(store.stats.slices_read))
        key = f"{name}-c{slots}"
        curves[key] = cum
        emit(f"slices_read/{key}", 0.0,
             f"cumulative={'|'.join(map(str, cum))}")
    c0 = curves["s4-i6-c0"][-1]
    unpacked = curves["s4-i1-c16"][-1]
    packed = curves["s4-i6-c16"][-1]
    emit("slices_read/derived_ordering", 0.0,
         f"c0={c0};i1_c14={unpacked};i6_c14={packed};"
         f"monotone={'yes' if c0 > unpacked > packed else 'NO'}")
    assert c0 > packed, "caching+packing must reduce slice reads"
    assert unpacked > packed, "temporal packing must reduce slice reads"


if __name__ == "__main__":
    run()
