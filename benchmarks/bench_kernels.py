"""Kernel micro-benchmarks: interpret-mode correctness + jnp-path wall time.

Wall times on this CPU container are RELATIVE indicators only (the Pallas
kernels target TPU; interpret mode executes the kernel body in Python).
What is asserted: kernel == oracle on production-relevant shapes; what is
reported: the jnp-reference throughput (XLA:CPU) as the derived column.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.semiring import MIN_PLUS, PLUS_MUL
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.semiring_spmm.ops import spmv_blocked


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run() -> None:
    rng = np.random.default_rng(0)

    # --- semiring SpMV: production tile size B=128 ------------------------
    B, nvb, T = 128, 8, 48
    cols = np.sort(rng.integers(0, nvb, T)).astype(np.int32)
    rows = rng.integers(0, nvb, T).astype(np.int32)
    for sr in (MIN_PLUS, PLUS_MUL):
        tiles = np.full((T, B, B), sr.zero, np.float32)
        for t in range(T):
            m = rng.random((B, B)) < 0.1
            tiles[t][m] = rng.random(int(m.sum()))
        x = rng.random(nvb * B).astype(np.float32)
        args = (jnp.asarray(tiles), jnp.asarray(rows), jnp.asarray(cols),
                jnp.asarray(x), sr)
        y_ref = spmv_blocked(*args, use_pallas=False)
        y_pal = spmv_blocked(*args, use_pallas=True, interpret=True)
        ref_np, pal_np = np.asarray(y_ref), np.asarray(y_pal)
        fin = np.isfinite(ref_np)
        ok = np.array_equal(fin, np.isfinite(pal_np)) and np.allclose(
            ref_np[fin], pal_np[fin], rtol=3e-5, atol=3e-5)
        jit_ref = jax.jit(lambda *a: spmv_blocked(*a, sr, use_pallas=False))
        dt = _time(jit_ref, *args[:4])
        flops = T * B * B * 2
        emit(f"kernels/spmv_{sr.name}", dt * 1e6,
             f"allclose={ok};jnp_gflops={flops / dt / 1e9:.2f}")
        assert ok

    # --- flash attention: 4k-token slice of the prefill shape -------------
    Bb, S, H, K, d = 1, 512, 8, 2, 128
    q = jnp.asarray(rng.normal(size=(Bb, S, H, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(Bb, S, K, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(Bb, S, K, d)), jnp.float32)
    o_ref = flash_attention(q, k, v, causal=True, use_pallas=False)
    o_pal = flash_attention(q, k, v, causal=True, use_pallas=True,
                            interpret=True)
    ok = bool(jnp.max(jnp.abs(o_ref - o_pal)) < 2e-4)
    jit_ref = jax.jit(lambda *a: flash_attention(*a, causal=True,
                                                 use_pallas=False))
    dt = _time(jit_ref, q, k, v)
    flops = 4 * Bb * H * S * S * d // 2  # causal half
    emit("kernels/flash_attention", dt * 1e6,
         f"allclose={ok};jnp_gflops={flops / dt / 1e9:.2f}")
    assert ok

    # --- decode attention: long-cache single token -------------------------
    Bb, S, H, K, d = 4, 4096, 8, 2, 128
    q = jnp.asarray(rng.normal(size=(Bb, H, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(Bb, S, K, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(Bb, S, K, d)), jnp.float32)
    lens = jnp.asarray(rng.integers(S // 2, S, Bb), jnp.int32)
    o_ref = decode_attention(q, k, v, lens, use_pallas=False)
    o_pal = decode_attention(q, k, v, lens, use_pallas=True, interpret=True)
    ok = bool(jnp.max(jnp.abs(o_ref - o_pal)) < 2e-4)
    jit_ref = jax.jit(lambda *a: decode_attention(*a, use_pallas=False))
    dt = _time(jit_ref, q, k, v, lens)
    bytes_moved = 2 * Bb * S * K * d * 4
    emit("kernels/decode_attention", dt * 1e6,
         f"allclose={ok};jnp_gbps={bytes_moved / dt / 1e9:.2f}")
    assert ok


if __name__ == "__main__":
    run()
