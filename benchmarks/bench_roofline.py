"""§Roofline table generation from the dry-run JSONL records.

Prints one CSV row per (arch x shape) cell plus the markdown table used in
EXPERIMENTS.md.  Does NOT recompile anything — the dry-run is the
measurement; this is the analysis.
"""
from __future__ import annotations

import os

from benchmarks.common import emit
from benchmarks.roofline import load_cells, markdown_table

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def run(write_markdown: bool = True) -> None:
    path = os.path.join(RESULTS, "dryrun_single.jsonl")
    if not os.path.exists(path):
        emit("roofline/missing", 0.0, f"run `python -m repro.launch.dryrun --all --out {path}` first")
        return
    cells = load_cells(path)
    for c in cells:
        if c.skipped:
            emit(f"roofline/{c.arch}/{c.shape}", 0.0, "skipped")
            continue
        emit(
            f"roofline/{c.arch}/{c.shape}",
            c.t_bound * 1e6,
            f"bound={c.dominant};t_comp_ms={c.t_compute*1e3:.2f};"
            f"t_mem_ms={c.t_memory*1e3:.2f};t_coll_ms={c.t_collective*1e3:.2f};"
            f"useful={c.useful_ratio:.2f};roofline_frac={c.roofline_fraction:.3f}",
        )
    if write_markdown:
        out = os.path.join(RESULTS, "roofline_table.md")
        with open(out, "w") as f:
            f.write(markdown_table(cells))
        emit("roofline/table_written", 0.0, out)


if __name__ == "__main__":
    run()
