"""Temporal engine economy: batched instance staging + unified runner vs
the per-instance Python loop the algorithms used before the engine.

Rows (also written to BENCH_temporal.json; field-by-field reference in
docs/BENCHMARKS.md):

* staging           — fill_local/fill_boundary per instance + np.stack
                      vs one fill_*_batch scatter for the whole collection
* gofs_staging      — per-(timestep, subgraph) instance reads vs the
                      GoFSStore.load_blocked bulk slice path
* async_staging     — end-to-end (GoFS stage + engine run): one-shot sync
                      staging vs the double-buffered SlicePrefetcher stream
                      (slice reads + tile fills overlap device execution)
* pagerank_runner   — per-instance device_graph + pagerank_run loop vs one
                      engine run scanning the staged (I, ...) tensors
* comm_backend      — the same engine run under each boundary-exchange
                      backend (repro.core.comm): dense psum/pmin vs
                      collective-permute ring vs host-side gather, stacked
                      in-process + dense-vs-ring on a forced host mesh
* mesh              — stacked vs temporal-parallel mesh execution on forced
                      host devices (subprocess; tracks scaling regressions)
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import BENCH_GRAPH, emit, store_for
from repro.core.blocked import build_blocked
from repro.core.engine import (
    TemporalEngine,
    min_plus_program,
    pagerank_program,
    source_init,
)
from repro.core.generator import generate_collection
from repro.core.partition import partition_graph
from repro.core.algorithms.pagerank import (
    edge_weights_for_instance,
    edge_weights_for_instances,
)

OUT_JSON = "BENCH_temporal.json"


def _time(fn, repeats: int = 3) -> float:
    fn()  # warm (jit/compile/cache)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> None:
    tsg = generate_collection(BENCH_GRAPH)
    tmpl = tsg.template
    assign = partition_graph(tmpl, BENCH_GRAPH.num_partitions,
                             seed=BENCH_GRAPH.seed)
    bg = build_blocked(tmpl, assign, BENCH_GRAPH.block_size)
    I = len(tsg)
    w = np.stack([tsg.edge_values(t, "latency") for t in range(I)])
    active = np.stack([tsg.edge_values(t, "active") for t in range(I)])
    results = {}

    # ---- staging: per-instance fill loop vs batched scatter ---------------
    def stage_loop():
        lt = np.stack([bg.fill_local(w[i]) for i in range(I)])
        bt = np.stack([bg.fill_boundary(w[i]) for i in range(I)])
        return lt, bt

    def stage_batch():
        return bg.fill_local_batch(w), bg.fill_boundary_batch(w)

    t_loop = _time(stage_loop)
    t_batch = _time(stage_batch)
    a, b = stage_loop(), stage_batch()
    assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
    emit("temporal/staging_loop", t_loop * 1e6, f"instances={I}")
    emit("temporal/staging_batch", t_batch * 1e6,
         f"speedup={t_loop / max(t_batch, 1e-12):.2f}x")
    results["staging"] = {
        "instances": I, "loop_s": t_loop, "batch_s": t_batch,
        "speedup": t_loop / max(t_batch, 1e-12),
    }

    # ---- GoFS staging: per-instance reads vs bulk slice path --------------
    store = store_for("s4-i6", cache_slots=14)

    def gofs_loop():
        out = np.empty((store.num_timesteps(), tmpl.num_edges), np.float32)
        for g in store.subgraph_ids():
            topo = store.get_topology(g)
            for t in range(store.num_timesteps()):
                sgi = store.get_instance(t, g)
                out[t, topo.local_edge_id] = sgi.local_edge_values["latency"]
                out[t, topo.remote_edge_id] = sgi.remote_edge_values["latency"]
        return out

    def gofs_bulk():
        return store.edge_attr_matrix("latency")

    t_gloop = _time(gofs_loop)
    t_gbulk = _time(gofs_bulk)
    assert np.allclose(gofs_loop(), gofs_bulk())
    emit("temporal/gofs_staging_loop", t_gloop * 1e6, "")
    emit("temporal/gofs_staging_bulk", t_gbulk * 1e6,
         f"speedup={t_gloop / max(t_gbulk, 1e-12):.2f}x")
    results["gofs_staging"] = {
        "loop_s": t_gloop, "bulk_s": t_gbulk,
        "speedup": t_gloop / max(t_gbulk, 1e-12),
    }

    # ---- async staging: end-to-end (stage + run), sync vs prefetched ------
    # cache_slots=0 so every repeat pays the real disk reads; sequential
    # SSSP is the paper's flagship temporal workload (carried distances).
    store0 = store_for("s4-i6", cache_slots=0)
    eng_t = TemporalEngine(bg)
    prog = min_plus_program("sssp", init=source_init(0))

    def e2e_sync():
        tiles, btiles = store0.load_blocked(bg, "latency")
        return eng_t.run(prog, tiles=tiles, btiles=btiles,
                         pattern="sequential")

    def e2e_async():
        stream = store0.load_blocked_stream(bg, "latency", prefetch_depth=2)
        return eng_t.run(prog, pattern="sequential", stream=stream)

    t_sync = _time(e2e_sync, repeats=3)
    t_async = _time(e2e_async, repeats=3)
    ra, rb = e2e_sync(), e2e_async()
    assert np.array_equal(ra.values, rb.values)  # staging must be invisible
    emit("temporal/e2e_sync_staging", t_sync * 1e6, f"instances={I}")
    emit("temporal/e2e_async_staging", t_async * 1e6,
         f"speedup={t_sync / max(t_async, 1e-12):.2f}x")
    results["async_staging"] = {
        "instances": I, "prefetch_depth": 2,
        "sync_s": t_sync, "async_s": t_async,
        "speedup": t_sync / max(t_async, 1e-12),
    }

    # ---- runner: per-instance pagerank loop vs one engine scan ------------
    from repro.core.superstep import Comm, device_graph, pagerank_run

    iters = 10
    V = tmpl.num_vertices

    def pr_loop():
        ranks = []
        for i in range(I):
            wi = edge_weights_for_instance(tmpl.src, active[i], V)
            dg = device_graph(bg, bg.fill_local(wi, zero=0.0),
                              bg.fill_boundary(wi, zero=0.0))
            r, _ = pagerank_run(dg, Comm(), num_vertices=V, iters=iters)
            ranks.append(bg.gather_vertex(np.asarray(r)))
        return np.stack(ranks)

    eng = TemporalEngine(bg)
    prog = pagerank_program(V, iters=iters)
    pw = edge_weights_for_instances(tmpl.src, active, V)

    def pr_engine():
        return eng.run(prog, pw, pattern="independent").values

    t_ploop = _time(pr_loop, repeats=2)
    t_peng = _time(pr_engine, repeats=2)
    assert np.abs(pr_loop() - pr_engine()).max() < 1e-6
    emit("temporal/pagerank_loop", t_ploop / I * 1e6,
         f"instances={I};iters={iters}")
    emit("temporal/pagerank_engine", t_peng / I * 1e6,
         f"speedup={t_ploop / max(t_peng, 1e-12):.2f}x")
    results["pagerank_runner"] = {
        "instances": I, "iters": iters,
        "loop_s": t_ploop, "engine_s": t_peng,
        "speedup": t_ploop / max(t_peng, 1e-12),
    }

    # ---- comm backends: one workload, three boundary exchanges ------------
    prog_c = min_plus_program("sssp", init=source_init(0))
    comm_engines = {
        b: TemporalEngine(bg, comm=b) for b in ("dense", "ring", "host")
    }

    def comm_run(b):
        return comm_engines[b].run(prog_c, w, pattern="sequential")

    ref_vals = comm_run("dense").values
    stacked = {}
    for b in ("dense", "ring", "host"):
        # backends must be invisible: bitwise parity before timing
        assert np.array_equal(comm_run(b).values, ref_vals), b
        stacked[f"{b}_s"] = _time(lambda b=b: comm_run(b))
        emit(f"temporal/comm_{b}_stacked", stacked[f"{b}_s"] * 1e6,
             f"instances={I}")
    stacked["host_vs_dense"] = stacked["host_s"] / max(stacked["dense_s"],
                                                       1e-12)
    results["comm_backend"] = {"instances": I, "stacked": stacked,
                               "mesh": _comm_mesh_rows()}

    # ---- mesh: stacked vs temporal-parallel shard_map (forced devices) ----
    results["mesh"] = _mesh_rows()

    with open(OUT_JSON, "w") as f:
        json.dump(results, f, indent=2)
    emit("temporal/json_written", 0.0, OUT_JSON)


# Runs in a subprocess: XLA_FLAGS must be set before jax imports, and the
# in-process benches above need the single real CPU device.
MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import numpy as np, jax
from repro.configs.base import GraphConfig
from repro.core.generator import generate_collection
from repro.core.partition import partition_graph
from repro.core.blocked import build_blocked
from repro.core.engine import TemporalEngine, pagerank_program
from repro.core.algorithms.pagerank import edge_weights_for_instances

cfg = GraphConfig(name="mesh-bench", num_vertices=1024, avg_degree=3.0,
                  num_instances=8, num_partitions=4, block_size=32, seed=7)
tsg = generate_collection(cfg)
tmpl = tsg.template
assign = partition_graph(tmpl, cfg.num_partitions, seed=cfg.seed)
bg = build_blocked(tmpl, assign, cfg.block_size)
I = len(tsg)
active = np.stack([tsg.edge_values(t, "active") for t in range(I)])
w = edge_weights_for_instances(tmpl.src, active, tmpl.num_vertices)
prog = pagerank_program(tmpl.num_vertices, iters=20)
mesh = jax.make_mesh((2, 4), ("data", "model"))
eng_s = TemporalEngine(bg)
eng_m = TemporalEngine(bg, mesh=mesh)


def best(fn, repeats=3):
    fn()
    t = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        t = min(t, time.perf_counter() - t0)
    return t


t_stacked = best(lambda: eng_s.run(prog, w, pattern="independent"))
t_mesh = best(lambda: eng_m.run(prog, w, pattern="independent"))
rs = eng_s.run(prog, w, pattern="independent")
rm = eng_m.run(prog, w, pattern="independent")
assert np.abs(rs.values - rm.values).max() < 1e-6
t_mesh_merge = best(
    lambda: eng_m.run(prog, w, pattern="eventually", merge="mean"))
print(json.dumps({
    "instances": I, "iters": 20, "devices": 8,
    "mesh_shape": {"data": 2, "model": 4},
    "stacked_s": t_stacked, "mesh_s": t_mesh,
    "mesh_eventually_merge_s": t_mesh_merge,
    "mesh_vs_stacked": t_stacked / max(t_mesh, 1e-12),
}))
"""


# Dense all-reduce vs collective-permute ring under shard_map; forced host
# devices need a fresh process (XLA_FLAGS before jax imports).
COMM_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import numpy as np, jax
from repro.configs.base import GraphConfig
from repro.core.generator import generate_collection
from repro.core.partition import partition_graph
from repro.core.blocked import build_blocked
from repro.core.engine import TemporalEngine, pagerank_program
from repro.core.algorithms.pagerank import edge_weights_for_instances

cfg = GraphConfig(name="comm-bench", num_vertices=1024, avg_degree=3.0,
                  num_instances=8, num_partitions=4, block_size=32, seed=7)
tsg = generate_collection(cfg)
tmpl = tsg.template
assign = partition_graph(tmpl, cfg.num_partitions, seed=cfg.seed)
bg = build_blocked(tmpl, assign, cfg.block_size)
I = len(tsg)
active = np.stack([tsg.edge_values(t, "active") for t in range(I)])
w = edge_weights_for_instances(tmpl.src, active, tmpl.num_vertices)
prog = pagerank_program(tmpl.num_vertices, iters=20)
mesh = jax.make_mesh((2, 4), ("data", "model"))
eng_d = TemporalEngine(bg, mesh=mesh)
eng_r = TemporalEngine(bg, mesh=mesh, comm="ring")


def best(fn, repeats=3):
    fn()
    t = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        t = min(t, time.perf_counter() - t0)
    return t


rd = eng_d.run(prog, w, pattern="independent")
rr = eng_r.run(prog, w, pattern="independent")
assert np.abs(rd.values - rr.values).max() < 1e-6  # documented reassociation
t_dense = best(lambda: eng_d.run(prog, w, pattern="independent"))
t_ring = best(lambda: eng_r.run(prog, w, pattern="independent"))
print(json.dumps({
    "instances": I, "iters": 20, "devices": 8,
    "mesh_shape": {"data": 2, "model": 4},
    "dense_s": t_dense, "ring_s": t_ring,
    "ring_vs_dense": t_ring / max(t_dense, 1e-12),
}))
"""


def _comm_mesh_rows() -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    r = subprocess.run(
        [sys.executable, "-c", COMM_MESH_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    if r.returncode != 0:
        emit("temporal/comm_mesh_failed", 0.0, r.stderr.strip()[-200:])
        return {"error": r.stderr.strip()[-2000:]}
    rows = json.loads(r.stdout.strip().splitlines()[-1])
    emit("temporal/comm_dense_mesh", rows["dense_s"] * 1e6,
         f"devices={rows['devices']}")
    emit("temporal/comm_ring_mesh", rows["ring_s"] * 1e6,
         f"ring_vs_dense={rows['ring_vs_dense']:.2f}x")
    return rows


def _mesh_rows() -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    r = subprocess.run(
        [sys.executable, "-c", MESH_SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    if r.returncode != 0:
        emit("temporal/mesh_failed", 0.0, r.stderr.strip()[-200:])
        return {"error": r.stderr.strip()[-2000:]}
    rows = json.loads(r.stdout.strip().splitlines()[-1])
    emit("temporal/mesh_stacked", rows["stacked_s"] * 1e6,
         f"devices={rows['devices']}")
    emit("temporal/mesh_temporal_parallel", rows["mesh_s"] * 1e6,
         f"mesh_vs_stacked={rows['mesh_vs_stacked']:.2f}x")
    emit("temporal/mesh_eventually_merge",
         rows["mesh_eventually_merge_s"] * 1e6, "")
    return rows


if __name__ == "__main__":
    run()
