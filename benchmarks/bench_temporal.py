"""Temporal engine economy: batched instance staging + unified runner vs
the per-instance Python loop the algorithms used before the engine.

Rows (also written to BENCH_temporal.json):

* staging           — fill_local/fill_boundary per instance + np.stack
                      vs one fill_*_batch scatter for the whole collection
* gofs_staging      — per-(timestep, subgraph) instance reads vs the
                      GoFSStore.load_blocked bulk slice path
* pagerank_runner   — per-instance device_graph + pagerank_run loop vs one
                      engine run scanning the staged (I, ...) tensors
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import BENCH_GRAPH, emit, store_for
from repro.core.blocked import build_blocked
from repro.core.engine import TemporalEngine, pagerank_program
from repro.core.generator import generate_collection
from repro.core.partition import partition_graph
from repro.core.algorithms.pagerank import (
    edge_weights_for_instance,
    edge_weights_for_instances,
)

OUT_JSON = "BENCH_temporal.json"


def _time(fn, repeats: int = 3) -> float:
    fn()  # warm (jit/compile/cache)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run() -> None:
    tsg = generate_collection(BENCH_GRAPH)
    tmpl = tsg.template
    assign = partition_graph(tmpl, BENCH_GRAPH.num_partitions,
                             seed=BENCH_GRAPH.seed)
    bg = build_blocked(tmpl, assign, BENCH_GRAPH.block_size)
    I = len(tsg)
    w = np.stack([tsg.edge_values(t, "latency") for t in range(I)])
    active = np.stack([tsg.edge_values(t, "active") for t in range(I)])
    results = {}

    # ---- staging: per-instance fill loop vs batched scatter ---------------
    def stage_loop():
        lt = np.stack([bg.fill_local(w[i]) for i in range(I)])
        bt = np.stack([bg.fill_boundary(w[i]) for i in range(I)])
        return lt, bt

    def stage_batch():
        return bg.fill_local_batch(w), bg.fill_boundary_batch(w)

    t_loop = _time(stage_loop)
    t_batch = _time(stage_batch)
    a, b = stage_loop(), stage_batch()
    assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
    emit("temporal/staging_loop", t_loop * 1e6, f"instances={I}")
    emit("temporal/staging_batch", t_batch * 1e6,
         f"speedup={t_loop / max(t_batch, 1e-12):.2f}x")
    results["staging"] = {
        "instances": I, "loop_s": t_loop, "batch_s": t_batch,
        "speedup": t_loop / max(t_batch, 1e-12),
    }

    # ---- GoFS staging: per-instance reads vs bulk slice path --------------
    store = store_for("s4-i6", cache_slots=14)

    def gofs_loop():
        out = np.empty((store.num_timesteps(), tmpl.num_edges), np.float32)
        for g in store.subgraph_ids():
            topo = store.get_topology(g)
            for t in range(store.num_timesteps()):
                sgi = store.get_instance(t, g)
                out[t, topo.local_edge_id] = sgi.local_edge_values["latency"]
                out[t, topo.remote_edge_id] = sgi.remote_edge_values["latency"]
        return out

    def gofs_bulk():
        return store.edge_attr_matrix("latency")

    t_gloop = _time(gofs_loop)
    t_gbulk = _time(gofs_bulk)
    assert np.allclose(gofs_loop(), gofs_bulk())
    emit("temporal/gofs_staging_loop", t_gloop * 1e6, "")
    emit("temporal/gofs_staging_bulk", t_gbulk * 1e6,
         f"speedup={t_gloop / max(t_gbulk, 1e-12):.2f}x")
    results["gofs_staging"] = {
        "loop_s": t_gloop, "bulk_s": t_gbulk,
        "speedup": t_gloop / max(t_gbulk, 1e-12),
    }

    # ---- runner: per-instance pagerank loop vs one engine scan ------------
    from repro.core.superstep import Comm, device_graph, pagerank_run

    iters = 10
    V = tmpl.num_vertices

    def pr_loop():
        ranks = []
        for i in range(I):
            wi = edge_weights_for_instance(tmpl.src, active[i], V)
            dg = device_graph(bg, bg.fill_local(wi, zero=0.0),
                              bg.fill_boundary(wi, zero=0.0))
            r, _ = pagerank_run(dg, Comm(), num_vertices=V, iters=iters)
            ranks.append(bg.gather_vertex(np.asarray(r)))
        return np.stack(ranks)

    eng = TemporalEngine(bg)
    prog = pagerank_program(V, iters=iters)
    pw = edge_weights_for_instances(tmpl.src, active, V)

    def pr_engine():
        return eng.run(prog, pw, pattern="independent").values

    t_ploop = _time(pr_loop, repeats=2)
    t_peng = _time(pr_engine, repeats=2)
    assert np.abs(pr_loop() - pr_engine()).max() < 1e-6
    emit("temporal/pagerank_loop", t_ploop / I * 1e6,
         f"instances={I};iters={iters}")
    emit("temporal/pagerank_engine", t_peng / I * 1e6,
         f"speedup={t_ploop / max(t_peng, 1e-12):.2f}x")
    results["pagerank_runner"] = {
        "instances": I, "iters": iters,
        "loop_s": t_ploop, "engine_s": t_peng,
        "speedup": t_ploop / max(t_peng, 1e-12),
    }

    with open(OUT_JSON, "w") as f:
        json.dump(results, f, indent=2)
    emit("temporal/json_written", 0.0, OUT_JSON)


if __name__ == "__main__":
    run()
