"""Temporal engine economy: batched instance staging + unified runner vs
the per-instance Python loop the algorithms used before the engine.

Rows (also written to BENCH_temporal.json; field-by-field reference in
docs/BENCHMARKS.md):

* staging           — fill_local/fill_boundary per instance + np.stack
                      vs one fill_*_batch scatter for the whole collection
* gofs_staging      — per-(timestep, subgraph) instance reads vs the
                      GoFSStore.load_blocked bulk slice path
* async_staging     — end-to-end (GoFS stage + engine run): one-shot sync
                      staging vs the double-buffered SlicePrefetcher stream
                      (slice reads + tile fills overlap device execution).
                      On a single-core box with page-cached files both
                      halves are CPU-bound, so this row records ~1.0x —
                      the staging-bound regime lives in the next row
* async_staging_bound — the same pipeline against a store with emulated
                      per-slice read latency (the paper's remote-disk
                      regime, where GoFS slices arrive from 12 hosts):
                      a deep prefetch window + parallel read workers
                      overlap the I/O waits with execution for a real
                      wall-clock win (sleeps burn no CPU, so the overlap
                      is measurable even single-core)
* delta_staging     — full sparse value loads vs the deploy-time delta
                      chain (deduplicated tile payload pools) on a
                      slowly-varying collection: bytes moved from the
                      store + load time, bitwise parity asserted
* warm_start        — cold fixpoints vs warm-started ones (instance t
                      seeded from t-1's converged state) on a
                      monotone-tightening chain workload: supersteps
                      saved + wall-clock speedup, bitwise parity asserted
* pagerank_runner   — per-instance device_graph + pagerank_run loop vs one
                      engine run scanning the staged (I, ...) tensors
* sparse            — dense vs block-sparse layout on a banded-activity
                      workload (~1/8 tile occupancy): staged bytes +
                      engine-step time, bitwise min-plus parity asserted
* use_pallas        — the semiring SpMV kernel (interpret mode) walking the
                      dense template tile list vs the packed active-tile
                      list with an nnz skip, vs the jnp oracle
* comm_backend      — the same engine run under each boundary-exchange
                      backend (repro.core.comm): dense psum/pmin vs
                      collective-permute ring vs host-side gather, stacked
                      in-process + dense-vs-ring on a forced host mesh
* mesh              — stacked vs temporal-parallel mesh execution on forced
                      host devices (subprocess; tracks scaling regressions)
* plan_overhead     — GopherSession.plan cost (auto-selection + cost
                      models, metadata only) vs executing the planned run
* shared_staging    — run_many over 3 analytics (sssp, nhop, tracking):
                      shared staging passes/bytes vs 3 independent runs,
                      results asserted identical
* serving           — warm GopherService answering Q=8 concurrent SSSP
                      point queries (source-axis batching + resident
                      staging cache) vs one cold session per query:
                      p50/p95 latency, throughput ratio, zero bytes
                      re-staged on repeat queries — results asserted
                      bitwise identical per source

``run(check=True)`` (CLI: ``--check``, also via ``benchmarks.run temporal
--check``) re-measures and compares against the committed
BENCH_temporal.json with per-row regression thresholds instead of
rewriting it; any violation exits nonzero.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import BENCH_GRAPH, deployments, emit, store_for
from repro.core.blocked import build_blocked
from repro.core.engine import (
    TemporalEngine,
    min_plus_program,
    pagerank_program,
    source_init,
)
from repro.core.generator import generate_collection
from repro.core.graph import GraphTemplate, TimeSeriesGraph
from repro.core.partition import partition_graph
from repro.core.semiring import INF
from repro.core.algorithms.pagerank import (
    edge_weights_for_instance,
    edge_weights_for_instances,
)
from repro.gofs import deploy_collection
from repro.gofs.slices import read_array_slice
from repro.gofs.store import GoFSStore

OUT_JSON = "BENCH_temporal.json"


class _SlowStore(GoFSStore):
    """GoFSStore with emulated per-slice read latency.

    The paper's GoFS serves slices from the local disks of 12 hosts; on
    this box every file is page-cached, so reads cost ~0 wall-clock and
    the prefetch pipeline has nothing to hide.  Sleeping inside the cache
    loader (cache misses only) restores the remote-read regime without
    burning CPU — which is also why the overlap shows up even on a
    single-core machine."""

    io_delay_s = 0.05

    def _load(self, pid, slice_name):
        path = os.path.join(self.root, f"part_{pid}", slice_name)

        def loader():
            time.sleep(self.io_delay_s)
            return read_array_slice(path, self.stats)

        return self.cache.get(f"{pid}/{slice_name}", loader)


def _delta_collection(cfg) -> TimeSeriesGraph:
    """Bench-scale slowly-varying collection: localized sparse support,
    ~1/8 of the live edges tightening per step — most tiles are bitwise
    unchanged between consecutive instances (the delta chain's regime)."""
    col = generate_collection(cfg)
    src = np.asarray(col.template.src)
    dst = np.asarray(col.template.dst)
    rng = np.random.default_rng(0)
    live = (src < 512) & (dst < 512)
    w = np.where(live, np.asarray(col.edge_values(0, "latency"), np.float32),
                 np.float32(INF)).astype(np.float32)
    ws = [w]
    idx = np.nonzero(live)[0]
    for _t in range(1, len(col)):
        w = ws[-1].copy()
        band = rng.choice(idx, size=max(1, len(idx) // 8), replace=False)
        w[band] = (w[band] * 0.7).astype(np.float32)
        ws.append(w)
    insts = []
    for t in range(len(col)):
        gi = col.instances[t]
        ev = dict(gi.edge_values)
        ev["latency"] = ws[t]
        insts.append(dataclasses.replace(gi, edge_values=ev))
    return TimeSeriesGraph(template=col.template, instances=insts)


def _time(fn, repeats: int = 3) -> float:
    fn()  # warm (jit/compile/cache)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _edge_bands(bg, src, dst, n_bands: int) -> np.ndarray:
    """Tile-aligned band id per edge: every edge of one tile shares
    (row_block, col_block), so banding on their sum keeps each tile
    single-band — instance *i* activating band ``i % n_bands`` yields
    ~1/n_bands tile occupancy, the GoFS-motivating sparse-activity
    regime."""
    B = bg.block_size
    local = bg.part_of[src] == bg.part_of[dst]
    slot_of = np.full(len(bg.part_of), 0, np.int64)
    pub = bg.bslot_of_src
    valid = pub >= 0
    slot_of[pub[valid]] = np.nonzero(valid)[0]
    row_blk = np.where(local, bg.local_of[src] // B, slot_of[src] // B)
    return (row_blk + bg.local_of[dst] // B) % n_bands


def run(check: bool = False) -> None:
    tsg = generate_collection(BENCH_GRAPH)
    tmpl = tsg.template
    assign = partition_graph(tmpl, BENCH_GRAPH.num_partitions,
                             seed=BENCH_GRAPH.seed)
    bg = build_blocked(tmpl, assign, BENCH_GRAPH.block_size)
    I = len(tsg)
    w = np.stack([tsg.edge_values(t, "latency") for t in range(I)])
    active = np.stack([tsg.edge_values(t, "active") for t in range(I)])
    results = {}

    # ---- staging: per-instance fill loop vs batched scatter ---------------
    def stage_loop():
        lt = np.stack([bg.fill_local(w[i]) for i in range(I)])
        bt = np.stack([bg.fill_boundary(w[i]) for i in range(I)])
        return lt, bt

    def stage_batch():
        return bg.fill_local_batch(w), bg.fill_boundary_batch(w)

    t_loop = _time(stage_loop)
    t_batch = _time(stage_batch)
    a, b = stage_loop(), stage_batch()
    assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
    emit("temporal/staging_loop", t_loop * 1e6, f"instances={I}")
    emit("temporal/staging_batch", t_batch * 1e6,
         f"speedup={t_loop / max(t_batch, 1e-12):.2f}x")
    results["staging"] = {
        "instances": I, "loop_s": t_loop, "batch_s": t_batch,
        "speedup": t_loop / max(t_batch, 1e-12),
    }

    # ---- GoFS staging: per-instance reads vs bulk slice path --------------
    store = store_for("s4-i6", cache_slots=14)

    def gofs_loop():
        out = np.empty((store.num_timesteps(), tmpl.num_edges), np.float32)
        for g in store.subgraph_ids():
            topo = store.get_topology(g)
            for t in range(store.num_timesteps()):
                sgi = store.get_instance(t, g)
                out[t, topo.local_edge_id] = sgi.local_edge_values["latency"]
                out[t, topo.remote_edge_id] = sgi.remote_edge_values["latency"]
        return out

    def gofs_bulk():
        return store.edge_attr_matrix("latency")

    t_gloop = _time(gofs_loop)
    t_gbulk = _time(gofs_bulk)
    assert np.allclose(gofs_loop(), gofs_bulk())
    emit("temporal/gofs_staging_loop", t_gloop * 1e6, "")
    emit("temporal/gofs_staging_bulk", t_gbulk * 1e6,
         f"speedup={t_gloop / max(t_gbulk, 1e-12):.2f}x")
    results["gofs_staging"] = {
        "loop_s": t_gloop, "bulk_s": t_gbulk,
        "speedup": t_gloop / max(t_gbulk, 1e-12),
    }

    # ---- async staging: end-to-end (stage + run), sync vs prefetched ------
    # cache_slots=0 so every repeat pays the real disk reads; sequential
    # SSSP is the paper's flagship temporal workload (carried distances).
    store0 = store_for("s4-i6", cache_slots=0)
    eng_t = TemporalEngine(bg)
    prog = min_plus_program("sssp", init=source_init(0))

    def e2e_sync():
        tiles, btiles = store0.load_blocked(bg, "latency")
        return eng_t.run(prog, tiles=tiles, btiles=btiles,
                         pattern="sequential")

    def e2e_async():
        stream = store0.load_blocked_stream(bg, "latency", prefetch_depth=2)
        return eng_t.run(prog, pattern="sequential", stream=stream)

    t_sync = _time(e2e_sync, repeats=3)
    t_async = _time(e2e_async, repeats=3)
    ra, rb = e2e_sync(), e2e_async()
    assert np.array_equal(ra.values, rb.values)  # staging must be invisible
    emit("temporal/e2e_sync_staging", t_sync * 1e6, f"instances={I}")
    emit("temporal/e2e_async_staging", t_async * 1e6,
         f"speedup={t_sync / max(t_async, 1e-12):.2f}x")
    results["async_staging"] = {
        "instances": I, "prefetch_depth": 2,
        "sync_s": t_sync, "async_s": t_async,
        "speedup": t_sync / max(t_async, 1e-12),
    }

    # ---- async staging, staging-bound: emulated remote-slice latency ------
    # s4-i1 (one instance per pack) maximizes slice count; cache_slots=0
    # forces every read through the delayed loader.  A depth-4 window with
    # 4 read workers keeps 3 chunks' reads in flight concurrently — the
    # sleeps overlap each other AND the engine run, so the pipeline wins
    # ~2x while the sync path pays every wait serially.
    _, root_i1 = deployments()["s4-i1"]
    slow = _SlowStore(root_i1, cache_slots=0)

    def bnd_sync():
        tiles, btiles = slow.load_blocked(bg, "latency")
        return eng_t.run(prog, tiles=tiles, btiles=btiles,
                         pattern="sequential")

    def bnd_async():
        stream = slow.load_blocked_stream(
            bg, "latency", prefetch_depth=4, chunk_instances=2,
            num_workers=4)
        return eng_t.run(prog, pattern="sequential", stream=stream)

    ra, rb = bnd_sync(), bnd_async()
    assert np.array_equal(ra.values, rb.values)  # staging must be invisible
    t_bsync = _time(bnd_sync, repeats=2)
    t_basync = _time(bnd_async, repeats=2)
    emit("temporal/e2e_sync_staging_bound", t_bsync * 1e6,
         f"io_delay_s={_SlowStore.io_delay_s}")
    emit("temporal/e2e_async_staging_bound", t_basync * 1e6,
         f"speedup={t_bsync / max(t_basync, 1e-12):.2f}x")
    results["async_staging_bound"] = {
        "instances": I, "io_delay_s": _SlowStore.io_delay_s,
        "prefetch_depth": 4, "chunk_instances": 2, "num_workers": 4,
        "sync_s": t_bsync, "async_s": t_basync,
        "speedup": t_bsync / max(t_basync, 1e-12),
    }

    # ---- delta staging: full sparse loads vs the deploy-time delta chain --
    # slowly-varying collection deployed once (skip-if-exists, like
    # common.deployments); c0 cache so timings pay real reads.  The byte
    # ratio is deterministic (recorded chain vs staged shapes).
    cfg_d = dataclasses.replace(BENCH_GRAPH, name="tr-bench-delta")
    root_d = "/tmp/gofs_bench_delta"
    if not os.path.exists(os.path.join(root_d, "collection.json")):
        deploy_collection(_delta_collection(cfg_d), cfg_d, root_d,
                          sparse_absent={"latency": INF})
    store_d = GoFSStore(root_d, cache_slots=0)
    full = store_d.load_blocked(bg, "latency", zero=INF, layout="sparse",
                                delta=False)
    dlt = store_d.load_blocked(bg, "latency", zero=INF, layout="sparse",
                               delta=True)
    # reconstruction must be bitwise-invisible before any byte counts
    assert np.array_equal(np.asarray(full.tiles), np.asarray(dlt.tiles))
    assert np.array_equal(np.asarray(full.btiles), np.asarray(dlt.btiles))
    assert full.source_bytes is None and dlt.source_bytes is not None
    t_dfull = _time(lambda: store_d.load_blocked(
        bg, "latency", zero=INF, layout="sparse", delta=False))
    t_ddelta = _time(lambda: store_d.load_blocked(
        bg, "latency", zero=INF, layout="sparse", delta=True))
    dratio, dmono = store_d.delta_stats("latency", zero=INF)
    bytes_full = full.staged_bytes()
    bratio = bytes_full / max(dlt.source_bytes, 1)
    emit("temporal/delta_staging_full", t_dfull * 1e6,
         f"bytes={bytes_full}")
    emit("temporal/delta_staging_delta", t_ddelta * 1e6,
         f"bytes_ratio={bratio:.2f}x;unique_ratio={dratio:.3f}")
    results["delta_staging"] = {
        "instances": I, "occupancy": full.occupancy(),
        "delta_unique_ratio": dratio, "delta_monotone": dmono,
        "staged_bytes_full": bytes_full,
        "source_bytes_delta": dlt.source_bytes,
        "staged_bytes_ratio": bratio,
        "full_load_s": t_dfull, "delta_load_s": t_ddelta,
        "load_speedup": t_dfull / max(t_ddelta, 1e-12),
    }

    # ---- warm start: cold fixpoints vs t-1-seeded ones --------------------
    # chain graph whose every block hop crosses partitions: a cold SSSP
    # fixpoint needs ~V/B supersteps per instance, while the warm seed is
    # already converged up to the slowly-tightening tail — the incremental
    # recompute the delta chain makes worth exploiting.
    Vw, Bw, Pw, Iw = 2048, 32, 4, 12
    tmpl_w = GraphTemplate(num_vertices=Vw, src=np.arange(Vw - 1),
                           dst=np.arange(1, Vw))
    bg_w = build_blocked(tmpl_w, (np.arange(Vw) // Bw) % Pw, Bw)
    w_w = np.ones((Iw, Vw - 1), np.float32)
    for t in range(1, Iw):
        w_w[t] = w_w[t - 1]
        w_w[t, -32:] *= 0.9  # tail tightens: monotone-improving
    prog_w = min_plus_program("sssp", init=source_init(0),
                              max_supersteps=256)
    eng_w = TemporalEngine(bg_w)
    cold = eng_w.run(prog_w, w_w, pattern="independent")
    warm = eng_w.run(prog_w, w_w, pattern="independent", warm_start=True)
    assert np.array_equal(cold.values, warm.values)  # warm is exact here
    saved = warm.supersteps_saved()
    t_cold = _time(lambda: eng_w.run(prog_w, w_w, pattern="independent"))
    t_warm = _time(lambda: eng_w.run(prog_w, w_w, pattern="independent",
                                     warm_start=True))
    emit("temporal/warm_start_cold", t_cold * 1e6,
         f"supersteps={int(cold.stats['supersteps'].sum())}")
    emit("temporal/warm_start_warm", t_warm * 1e6,
         f"speedup={t_cold / max(t_warm, 1e-12):.2f}x;"
         f"saved={int(saved.sum())}")
    results["warm_start"] = {
        "instances": Iw, "num_vertices": Vw,
        "supersteps_cold": int(cold.stats["supersteps"].sum()),
        "supersteps_warm": int(warm.stats["supersteps"].sum()),
        "supersteps_saved": int(saved.sum()),
        "cold_s": t_cold, "warm_s": t_warm,
        "speedup": t_cold / max(t_warm, 1e-12),
    }

    # ---- gopher session: plan overhead ------------------------------------
    # planning is metadata-only (blocked structure + recorded maps + comm
    # cost model); the row gates that it stays a rounding error next to
    # the run it configures.
    from repro.gopher import GopherSession

    t0_sess = time.perf_counter()
    sess_po = GopherSession(store, block_size=BENCH_GRAPH.block_size)
    t_sess_init = time.perf_counter() - t0_sess
    t_plan = _time(lambda: sess_po.plan("sssp", source=0))
    plan_po = sess_po.plan("sssp", source=0)
    t_planned_run = _time(lambda: sess_po.run(plan_po), repeats=2)
    emit("temporal/gopher_plan", t_plan * 1e6,
         f"staging={plan_po.staging.value};layout={plan_po.layout.value}")
    emit("temporal/gopher_planned_run", t_planned_run * 1e6,
         f"plan_frac={t_plan / max(t_planned_run, 1e-12):.4f}")
    results["plan_overhead"] = {
        "session_init_s": t_sess_init,
        "plan_s": t_plan,
        "run_s": t_planned_run,
        "frac": t_plan / max(t_planned_run, 1e-12),
    }

    # ---- gopher session: shared staging (run_many) ------------------------
    # three analytics over one collection: sssp + nhop share the latency
    # batch, nhop's hop probe + tracking share the unit-weight batch, so
    # the shared pass stages each distinct batch once while 3 independent
    # runs stage 2x each.  The byte ratio is shape-derived (deterministic);
    # results are asserted identical before timing counts.
    def _sh_session():
        return GopherSession(store_for("s4-i6", cache_slots=14),
                             block_size=BENCH_GRAPH.block_size)

    def _sh_plans(s):
        return [s.plan("sssp", source=0),
                s.plan("nhop", source=0, n_hops=6),
                s.plan("tracking", plate=3, initial_vertex=0)]

    s_sh = _sh_session()
    t0 = time.perf_counter()
    r_shared = s_sh.run_many(_sh_plans(s_sh))
    t_shared = time.perf_counter() - t0
    rep_sh = dict(s_sh.last_run_report)

    t0 = time.perf_counter()
    bytes_ind = passes_ind = 0
    singles = []
    for p in _sh_plans(_sh_session()):
        s1 = _sh_session()
        singles.append(s1.run(p))
        bytes_ind += s1.last_run_report["staged_bytes"]
        passes_ind += s1.last_run_report["staging_passes"]
    t_indep = time.perf_counter() - t0
    for a, b in zip(r_shared, singles):  # sharing must be invisible
        if a.engine is not None and b.engine is not None:
            assert np.array_equal(a.engine.values, b.engine.values)
        for k in a.output:
            assert np.array_equal(a.output[k], b.output[k]), k
    ratio = bytes_ind / max(rep_sh["staged_bytes"], 1)
    emit("temporal/shared_staging", t_shared * 1e6,
         f"bytes_ratio={ratio:.2f}x;passes={rep_sh['staging_passes']}"
         f"vs{passes_ind}")
    emit("temporal/independent_staging", t_indep * 1e6,
         f"speedup={t_indep / max(t_shared, 1e-12):.2f}x")
    results["shared_staging"] = {
        "analytics": rep_sh["analytics"],
        "staged_bytes_shared": rep_sh["staged_bytes"],
        "staged_bytes_independent": bytes_ind,
        "staged_bytes_ratio": ratio,
        "staging_passes_shared": rep_sh["staging_passes"],
        "staging_passes_independent": passes_ind,
        "shared_s": t_shared,
        "independent_s": t_indep,
        "speedup": t_indep / max(t_shared, 1e-12),
    }

    # ---- gopher service: warm serving vs one-session-per-query ------------
    results["serving"] = serving_row()

    # ---- streaming ingestion: live tail steps vs full re-runs -------------
    results["streaming_ingest"] = streaming_ingest_row()

    # ---- runner: per-instance pagerank loop vs one engine scan ------------
    from repro.core.superstep import Comm, device_graph, pagerank_run

    iters = 10
    V = tmpl.num_vertices

    def pr_loop():
        ranks = []
        for i in range(I):
            wi = edge_weights_for_instance(tmpl.src, active[i], V)
            dg = device_graph(bg, bg.fill_local(wi, zero=0.0),
                              bg.fill_boundary(wi, zero=0.0))
            r, _ = pagerank_run(dg, Comm(), num_vertices=V, iters=iters)
            ranks.append(bg.gather_vertex(np.asarray(r)))
        return np.stack(ranks)

    eng = TemporalEngine(bg)
    prog = pagerank_program(V, iters=iters)
    pw = edge_weights_for_instances(tmpl.src, active, V)

    def pr_engine():
        return eng.run(prog, pw, pattern="independent").values

    t_ploop = _time(pr_loop, repeats=2)
    t_peng = _time(pr_engine, repeats=2)
    assert np.abs(pr_loop() - pr_engine()).max() < 1e-6
    emit("temporal/pagerank_loop", t_ploop / I * 1e6,
         f"instances={I};iters={iters}")
    emit("temporal/pagerank_engine", t_peng / I * 1e6,
         f"speedup={t_ploop / max(t_peng, 1e-12):.2f}x")
    results["pagerank_runner"] = {
        "instances": I, "iters": iters,
        "loop_s": t_ploop, "engine_s": t_peng,
        "speedup": t_ploop / max(t_peng, 1e-12),
    }

    # ---- block-sparse layout: staged bytes + engine-step economy ----------
    # banded temporal activity (~1/8 tile occupancy): per instance only one
    # of n_bands tile-aligned bands is live — the regime the sparse layout
    # targets (most inter-subgraph tiles empty per timestep).
    n_bands = 8
    band = _edge_bands(bg, tmpl.src, tmpl.dst, n_bands)
    live = band[None, :] == (np.arange(I) % n_bands)[:, None]  # (I, E)
    eng_d = TemporalEngine(bg)
    eng_sp = TemporalEngine(bg, layout="sparse")

    # parity first (bitwise for min-plus), on banded SSSP latencies
    wb = np.where(live, w, np.inf).astype(np.float32)
    prog_s = min_plus_program("sssp", init=source_init(0))
    r_dense = eng_d.run(prog_s, wb, pattern="sequential")
    r_sparse = eng_sp.run(prog_s, wb, pattern="sequential")
    assert np.array_equal(r_dense.values, r_sparse.values)  # layout invisible

    # timing on fixed-work PageRank (20 supersteps — no convergence noise)
    sp_iters = 20
    pw_b = edge_weights_for_instances(tmpl.src, live.astype(np.float32), V)
    prog_p = pagerank_program(V, iters=sp_iters)
    tiles_d, btiles_d = eng_d.stage(pw_b, prog_p.zero_fill)
    sp = eng_sp.stage_sparse(pw_b, prog_p.zero_fill)
    rp_d = eng_d.run(prog_p, tiles=tiles_d, btiles=btiles_d,
                     pattern="independent")
    rp_s = eng_sp.run(prog_p, sparse=sp, pattern="independent")
    assert np.abs(rp_d.values - rp_s.values).max() < 1e-6
    t_dstep = _time(lambda: eng_d.run(prog_p, tiles=tiles_d,
                                      btiles=btiles_d,
                                      pattern="independent"))
    t_sstep = _time(lambda: eng_sp.run(prog_p, sparse=sp,
                                       pattern="independent"))
    bytes_d = int(np.asarray(tiles_d).nbytes + np.asarray(btiles_d).nbytes)
    bytes_s = sp.staged_bytes()
    occ = sp.occupancy()
    emit("temporal/sparse_engine_dense", t_dstep * 1e6,
         f"tiles={bg.t_max}+{bg.tb_max}")
    emit("temporal/sparse_engine_sparse", t_sstep * 1e6,
         f"speedup={t_dstep / max(t_sstep, 1e-12):.2f}x;"
         f"occupancy={occ:.3f}")
    emit("temporal/sparse_staged_bytes", float(bytes_s),
         f"dense={bytes_d};ratio={bytes_d / max(bytes_s, 1):.2f}x")
    results["sparse"] = {
        "instances": I, "iters": sp_iters, "n_bands": n_bands,
        "occupancy": occ,
        "bucket": sp.bucket, "bbucket": sp.bbucket,
        "t_max": bg.t_max, "tb_max": bg.tb_max,
        "dense_step_s": t_dstep, "sparse_step_s": t_sstep,
        "step_speedup": t_dstep / max(t_sstep, 1e-12),
        "staged_bytes_dense": bytes_d, "staged_bytes_sparse": bytes_s,
        "staged_bytes_ratio": bytes_d / max(bytes_s, 1),
    }

    # ---- use_pallas: kernel walking dense vs packed active-tile lists -----
    from repro.core.semiring import MIN_PLUS
    from repro.kernels.semiring_spmm.ops import spmv_blocked
    import jax.numpy as jnp

    p0 = 0
    dt = jnp.asarray(bg.fill_local(wb[0])[p0])
    drows = jnp.asarray(bg.tiles_rc[p0, :, 0])
    dcols = jnp.asarray(bg.tiles_rc[p0, :, 1])
    sp_mp = bg.stage_sparse(wb[:1])  # same instance, min-plus zero fill
    st = jnp.asarray(sp_mp.tiles[0, p0])
    srows = jnp.asarray(sp_mp.rows[0, p0])
    scols = jnp.asarray(sp_mp.cols[0, p0])
    snnz = jnp.asarray(int(sp_mp.nnz[0, p0]), jnp.int32)
    x = jnp.asarray(np.random.default_rng(0).random(bg.vp), jnp.float32)

    def k_dense():
        return spmv_blocked(dt, drows, dcols, x, MIN_PLUS,
                            use_pallas=True, interpret=True).block_until_ready()

    def k_sparse():
        return spmv_blocked(st, srows, scols, x, MIN_PLUS, use_pallas=True,
                            interpret=True, nnz=snnz,
                            n_out_blocks=bg.vp // bg.block_size,
                            ).block_until_ready()

    def k_ref():
        return spmv_blocked(st, srows, scols, x, MIN_PLUS, use_pallas=False,
                            n_out_blocks=bg.vp // bg.block_size,
                            ).block_until_ready()

    yk_d, yk_s, yk_r = np.asarray(k_dense()), np.asarray(k_sparse()), \
        np.asarray(k_ref())
    assert np.array_equal(yk_s, yk_r) and np.array_equal(yk_d, yk_s)
    t_kd, t_ks, t_kr = _time(k_dense), _time(k_sparse), _time(k_ref)
    emit("temporal/use_pallas_dense_walk", t_kd * 1e6,
         f"tiles={int(dt.shape[0])};interpret=True")
    emit("temporal/use_pallas_sparse_walk", t_ks * 1e6,
         f"tiles={int(st.shape[0])};nnz={int(snnz)}")
    results["use_pallas"] = {
        "interpret": True, "block_size": bg.block_size,
        "tiles_dense": int(dt.shape[0]), "tiles_packed": int(st.shape[0]),
        "nnz": int(snnz),
        "pallas_dense_s": t_kd, "pallas_sparse_s": t_ks, "jnp_sparse_s": t_kr,
        "dense_vs_sparse": t_kd / max(t_ks, 1e-12),
    }

    # ---- fused superstep kernel: one pallas_call per local stage ----------
    # The gated metrics are jaxpr-derived and DETERMINISTIC: the fused
    # path must lower its whole local stage (tile walk + semiring combine
    # + halt vote) to exactly one pallas_call with no state-sized XLA
    # reduction left outside the kernel, and must need strictly fewer
    # equations than the per-stage spmv sweep + separate vote.  Interpret
    # -mode wall clocks are recorded for the record but NOT gated (on CPU
    # the interpreter dominates; the structural counts are what transfer
    # to the TPU lowering).
    import jax

    from repro.core.superstep import (_fused_sweep_vote, _local_sweep,
                                      device_graph)

    dgf = device_graph(bg, bg.fill_local(wb[0]), bg.fill_boundary(wb[0]))
    x0f = jnp.asarray(np.where(np.asarray(dgf.vmask), 1.0, np.inf),
                      jnp.float32)

    def _all_eqns(jx):
        out, stack = [], list(jx.jaxpr.eqns)
        while stack:
            e = stack.pop()
            out.append(e)
            for sub in e.params.values():
                if hasattr(sub, "jaxpr"):
                    stack.extend(sub.jaxpr.eqns)
        return out

    def fused_sweep(xx):
        return _fused_sweep_vote(xx, dgf, MIN_PLUS, True)

    def spmv_sweep_vote(xx):
        xn = _local_sweep(xx, dgf, MIN_PLUS, ("spmv", True))
        return xn, jnp.any(jnp.where(dgf.vmask, xn != xx, False))

    eq_f = _all_eqns(jax.make_jaxpr(fused_sweep)(x0f))
    eq_s = _all_eqns(jax.make_jaxpr(spmv_sweep_vote)(x0f))
    n_pallas_f = sum(e.primitive.name == "pallas_call" for e in eq_f)
    state_elems_cap = int(dgf.n_parts)  # reduces over flags are fine
    n_state_reduces = sum(
        1 for e in eq_f
        if e.primitive.name in ("reduce_or", "reduce_and",
                                "reduce_max", "reduce_min")
        and int(np.prod(e.invars[0].aval.shape)) > state_elems_cap)

    # parity before timing, then interpret-mode wall clocks (ungated)
    jf = jax.jit(fused_sweep)
    js = jax.jit(spmv_sweep_vote)
    xf, chf = jf(x0f)
    xs_, chs = js(x0f)
    assert np.array_equal(np.asarray(xf), np.asarray(xs_))
    assert bool(np.max(np.asarray(chf)) > 0) == bool(np.asarray(chs))
    t_fsweep = _time(lambda: jax.block_until_ready(jf(x0f)))
    t_ssweep = _time(lambda: jax.block_until_ready(js(x0f)))

    # end-to-end engine runs, banded SSSP, all three kernel modes
    prog_f = min_plus_program("sssp", init=source_init(0))
    eng_fu = TemporalEngine(bg, use_pallas="fused")
    eng_pv = TemporalEngine(bg, use_pallas="spmv")
    r_or = eng_d.run(prog_f, wb, pattern="sequential")
    r_fu = eng_fu.run(prog_f, wb, pattern="sequential")
    r_pv = eng_pv.run(prog_f, wb, pattern="sequential")
    assert np.array_equal(r_or.values, r_fu.values)
    assert np.array_equal(r_or.values, r_pv.values)
    t_eor = _time(lambda: eng_d.run(prog_f, wb, pattern="sequential"),
                  repeats=2)
    t_efu = _time(lambda: eng_fu.run(prog_f, wb, pattern="sequential"),
                  repeats=2)
    t_epv = _time(lambda: eng_pv.run(prog_f, wb, pattern="sequential"),
                  repeats=2)
    emit("temporal/fused_superstep_pallas_calls", float(n_pallas_f),
         f"eqns={len(eq_f)};spmv_eqns={len(eq_s)}")
    emit("temporal/fused_superstep_sweep", t_fsweep * 1e6,
         f"spmv={t_ssweep * 1e6:.0f}us;interpret=True")
    results["fused_superstep"] = {
        "interpret": True,
        "fused_pallas_calls": n_pallas_f,
        "state_vote_reduces": n_state_reduces,
        "sweep_eqns_fused": len(eq_f),
        "sweep_eqns_spmv": len(eq_s),
        "eqn_ratio": len(eq_s) / max(len(eq_f), 1),
        "sweep_fused_s": t_fsweep, "sweep_spmv_s": t_ssweep,
        "engine_oracle_s": t_eor, "engine_spmv_s": t_epv,
        "engine_fused_s": t_efu,
    }

    # ---- comm backends: one workload, three boundary exchanges ------------
    prog_c = min_plus_program("sssp", init=source_init(0))
    comm_engines = {
        b: TemporalEngine(bg, comm=b) for b in ("dense", "ring", "host")
    }

    def comm_run(b):
        return comm_engines[b].run(prog_c, w, pattern="sequential")

    ref_vals = comm_run("dense").values
    stacked = {}
    for b in ("dense", "ring", "host"):
        # backends must be invisible: bitwise parity before timing
        assert np.array_equal(comm_run(b).values, ref_vals), b
        stacked[f"{b}_s"] = _time(lambda b=b: comm_run(b))
        emit(f"temporal/comm_{b}_stacked", stacked[f"{b}_s"] * 1e6,
             f"instances={I}")
    stacked["host_vs_dense"] = stacked["host_s"] / max(stacked["dense_s"],
                                                       1e-12)
    results["comm_backend"] = {"instances": I, "stacked": stacked,
                               "mesh": _comm_mesh_rows()}

    # ---- mesh: stacked vs temporal-parallel shard_map (forced devices) ----
    results["mesh"] = _mesh_rows()

    # ---- cluster: 2-process shard-local staging + inter-process gather ----
    results["cluster_scaling"] = _cluster_scaling_row()

    if check:
        failures = check_against_baseline(results)
        if "error" in results["cluster_scaling"]:
            failures.append("cluster_scaling: 2-process parity run failed — "
                            + results["cluster_scaling"]["error"][-200:])
        for f_ in failures:
            emit("temporal/check_failed", 0.0, f_)
        if failures:
            print(f"[bench_temporal --check] {len(failures)} regression(s):",
                  file=sys.stderr)
            for f_ in failures:
                print(f"  {f_}", file=sys.stderr)
            raise SystemExit(1)
        emit("temporal/check_ok", 0.0, f"rows={len(THRESHOLDS)}")
        return

    with open(OUT_JSON, "w") as f:
        json.dump(results, f, indent=2)
    emit("temporal/json_written", 0.0, OUT_JSON)


def serving_row() -> dict:
    """The serving economy row (standalone so the slow tier-1 test can run
    just this): a warm :class:`~repro.gopher.GopherService` answering Q=8
    concurrent SSSP point queries vs the no-serving-layer alternative —
    one cold :class:`~repro.gopher.GopherSession` per query.  Batched
    results are asserted bitwise identical to the per-query runs before
    any timing counts; the repeat-query staging report must show ZERO
    bytes re-staged (the warm-cache acceptance criterion).

    The collection is interactive-scale (deployed once, like the delta
    row's): the serving layer's regime is many small point queries where
    session spin-up (staging passes + jit compiles, paid per cold
    session) rivals the engine run — the main bench collection's
    multi-second dense runs would bury that economy under raw semiring
    compute on a CPU box."""
    from repro.gopher import GopherService, GopherSession

    cfg_s = dataclasses.replace(
        BENCH_GRAPH, name="tr-bench-serve", num_vertices=1024,
        num_instances=8, block_size=32)
    root_s = "/tmp/gofs_bench_serve"
    if not os.path.exists(os.path.join(root_s, "collection.json")):
        deploy_collection(generate_collection(cfg_s), cfg_s, root_s)

    Q = 8
    sources = list(range(Q))
    reqs = [("sssp", {"source": s}) for s in sources]
    svc = GopherService(GoFSStore(root_s, cache_slots=14),
                        block_size=cfg_s.block_size).start()
    svc.query("sssp", source=sources[0])  # warm: stage + compile
    svc.query("sssp", source=sources[0])  # repeat: must re-stage nothing
    restaged = int(svc.session.last_run_report["staged_bytes"])
    repeat_passes = int(svc.session.last_run_report["staging_passes"])

    def served():
        return svc.query_many(reqs)

    t_warm_batch = _time(served, repeats=3)
    outs = served()
    rep = svc.report()
    svc.stop()

    # baseline: a fresh session per query (cold staging, cold jit)
    def per_query():
        res = []
        for s in sources:
            sess = GopherSession(GoFSStore(root_s, cache_slots=14),
                                 block_size=cfg_s.block_size)
            res.append(sess.run(sess.plan("sssp", source=s)))
        return res

    singles = per_query()
    for a, b in zip(outs, singles):  # batching must be invisible
        assert np.array_equal(a.output["final"], b.output["final"])
    t_per_query = _time(per_query, repeats=2)

    ratio = t_per_query / max(t_warm_batch, 1e-12)
    emit("temporal/serving_per_query", t_per_query * 1e6, f"q={Q}")
    emit("temporal/serving_warm_batched", t_warm_batch * 1e6,
         f"throughput_ratio={ratio:.2f}x;"
         f"p95_ms={rep['p95_ms']:.1f};restaged={restaged}")
    return {
        "q": Q,
        "p50_ms": rep["p50_ms"], "p95_ms": rep["p95_ms"],
        "widest_batch": rep["widest_batch"],
        "warm_batch_s": t_warm_batch, "per_query_s": t_per_query,
        "throughput_ratio": ratio,
        "restaged_bytes_repeat": restaged,
        "restaging_passes_repeat": repeat_passes,
    }


def streaming_ingest_row() -> dict:
    """The streaming-ingestion row (standalone so the slow tier-1 test can
    run just this): a live-tailed session absorbing appended instances vs
    re-running the analytic from scratch after every append.

    A prefix of an interactive-scale collection is deployed, a
    ``GopherSession.tail`` establishes the initial full result, then the
    remaining instances are appended batch-by-batch
    (:func:`~repro.gofs.append_instances`) with one tail step timed per
    append — refresh (manifest poll + tail cache invalidation) plus one
    warm incremental engine pass over just the appended batch.  The tailed
    history is asserted bitwise identical to a cold full run over the
    grown collection BEFORE any timing counts.  The gated ``speedup`` is
    cold-full-re-run wall time over the steady-state tail step (both
    jit-warm: the tail loop repeats one suffix shape, the parity check
    compiles the full-size runner)."""
    import shutil

    from repro.gofs import append_instances
    from repro.gopher import GopherSession

    cfg_t = dataclasses.replace(
        BENCH_GRAPH, name="tr-bench-stream", num_vertices=1024,
        num_instances=12, block_size=32)
    tsg_t = generate_collection(cfg_t)
    prefix, batch = 4, 2
    root_t = "/tmp/gofs_bench_stream"
    # always redeploy the prefix: the row itself grows the collection, so
    # a previous run's grown deployment must not short-circuit the appends
    if os.path.exists(root_t):
        shutil.rmtree(root_t)
    deploy_collection(
        TimeSeriesGraph(template=tsg_t.template,
                        instances=tsg_t.instances[:prefix]),
        cfg_t, root_t)

    sess = GopherSession(GoFSStore(root_t, cache_slots=14),
                         block_size=cfg_t.block_size,
                         staging_cache_bytes=256 << 20)
    u = sess.tail("sssp", source=0)
    assert u.mode == "full", u.mode
    tail_steps = []
    for k in range(prefix, len(tsg_t), batch):
        append_instances(
            TimeSeriesGraph(template=tsg_t.template,
                            instances=tsg_t.instances[k:k + batch]),
            root_t)
        t0 = time.perf_counter()
        u = sess.tail("sssp", source=0)
        tail_steps.append(time.perf_counter() - t0)
        assert u.mode == "incremental", u.mode

    # exactness gates the row: the tailed full history must be bitwise
    # identical to a cold run over the grown collection
    cold = GopherSession(GoFSStore(root_t, cache_slots=14),
                         block_size=cfg_t.block_size)
    ref = cold.run(cold.plan("sssp", source=0))
    assert np.array_equal(u.result.engine.values, ref.engine.values)
    assert np.array_equal(u.result.output["final"], ref.output["final"])

    # baseline: no streaming layer — re-run from scratch over the grown
    # collection (fresh session: staging passes + planning paid again)
    def full_rerun():
        s = GopherSession(GoFSStore(root_t, cache_slots=14),
                          block_size=cfg_t.block_size)
        return s.run(s.plan("sssp", source=0))

    t_full = _time(full_rerun, repeats=2)
    # warm-session full re-run (jit + session warm, staging re-done):
    # the strongest non-streaming alternative, reported for context
    t_full_warm = _time(lambda: cold.run(cold.plan("sssp", source=0)),
                        repeats=2)
    # steady-state step: first append pays the suffix-shape compile
    t_tail = min(tail_steps[1:]) if len(tail_steps) > 1 else tail_steps[0]
    speedup = t_full / max(t_tail, 1e-12)
    emit("temporal/streaming_full_rerun", t_full * 1e6,
         f"instances={len(tsg_t)}")
    emit("temporal/streaming_tail_step", t_tail * 1e6,
         f"speedup={speedup:.2f}x;appends={len(tail_steps)};batch={batch}")
    return {
        "instances_total": len(tsg_t), "prefix": prefix, "batch": batch,
        "incremental_steps": len(tail_steps),
        "tail_step_s": t_tail,
        "tail_step_first_s": tail_steps[0],
        "full_rerun_s": t_full,
        "full_rerun_warm_s": t_full_warm,
        "speedup": speedup,
        "speedup_vs_warm": t_full_warm / max(t_tail, 1e-12),
    }


# Per-row regression gates for ``--check``: (row, field) -> (kind, floor,
# rel_frac).  ``min``: fresh value must be >= max(floor, rel_frac *
# baseline) — the absolute floor catches a lost optimization outright, the
# relative guard (None = disabled) catches slow drift vs the committed
# BENCH_temporal.json on rows stable enough to compare run-to-run.
# ``max``: fresh value must stay <= ceiling (deterministic quantities
# only).  Rows whose ratio is dominated by disk/cache or thread-scheduling
# noise (gofs_staging swings 2x between runs; async_staging shares cores
# between fill threads and the engine on CPU boxes) gate on the absolute
# floor alone.
THRESHOLDS = {
    ("staging", "speedup"): ("min", 1.3, 0.5),
    ("gofs_staging", "speedup"): ("min", 50.0, None),
    ("async_staging", "speedup"): ("min", 0.5, None),
    # staging-bound variant: deterministic sleeps dominate the sync path,
    # so the overlap win is stable run-to-run (~2x measured single-core)
    ("async_staging_bound", "speedup"): ("min", 1.5, 0.6),
    # deterministic (recorded chain vs staged shapes): the acceptance
    # target for the delta dedupe — and the load must not get slower
    ("delta_staging", "staged_bytes_ratio"): ("min", 2.0, 0.9),
    ("delta_staging", "load_speedup"): ("min", 0.8, None),
    # warm-started fixpoints: supersteps saved is deterministic, the
    # wall-clock win tracks it (~9x measured)
    ("warm_start", "speedup"): ("min", 1.5, 0.5),
    ("warm_start", "supersteps_saved"): ("min", 100.0, 0.9),
    ("pagerank_runner", "speedup"): ("min", 1.3, 0.5),
    ("sparse", "step_speedup"): ("min", 1.5, 0.5),
    # deterministic (shape-derived): the acceptance targets themselves
    ("sparse", "staged_bytes_ratio"): ("min", 4.0, 0.9),
    ("sparse", "occupancy"): ("max", 0.25, None),
    # gopher session: planning must stay a rounding error vs the run it
    # configures; shared staging must amortize (byte ratio shape-derived)
    ("plan_overhead", "frac"): ("max", 0.1, None),
    ("shared_staging", "staged_bytes_ratio"): ("min", 1.5, 0.9),
    # warm serving: the acceptance targets — >=2x throughput over one
    # cold session per query at Q=8, and a repeat query on a warm cache
    # re-stages NOTHING (both deterministic enough to gate hard; the
    # ratio also folds in jit-compile amortization, so it sits far above
    # the floor in practice)
    ("serving", "throughput_ratio"): ("min", 2.0, 0.5),
    ("serving", "restaged_bytes_repeat"): ("max", 0.0, None),
    ("serving", "restaging_passes_repeat"): ("max", 0.0, None),
    # fused superstep kernel: jaxpr-derived structural counts, fully
    # deterministic — the whole local stage must stay ONE pallas_call,
    # the halt vote must never fall out of the kernel as a state-sized
    # XLA reduce, and the fused lowering must stay strictly leaner than
    # the per-stage spmv sweep + separate vote (floor kept conservative
    # so a jax upgrade shifting eqn counts by noise does not trip it)
    ("fused_superstep", "fused_pallas_calls"): ("max", 1.0, None),
    ("fused_superstep", "state_vote_reduces"): ("max", 0.0, None),
    ("fused_superstep", "eqn_ratio"): ("min", 1.1, None),
    # streaming ingestion: the acceptance target — a steady-state tail
    # step (warm incremental recompute of one appended batch) must beat a
    # cold full re-run over the grown collection by >=3x; the step count
    # is deterministic (collection size / batch)
    ("streaming_ingest", "speedup"): ("min", 3.0, 0.5),
    ("streaming_ingest", "incremental_steps"): ("min", 4.0, None),
    # 2-process cluster lane: deterministic (shard-derived) — every host
    # must materialize strictly less than the single-process staging cost
    # (exactly 1/2 with 2 procs on an even partition split; cap leaves
    # headroom for odd partition counts where low ranks take the
    # remainder).  Parity itself is asserted inside the subprocess — a
    # failed run surfaces as an explicit --check failure, not a row.
    ("cluster_scaling", "max_per_host_fraction"): ("max", 0.75, None),
}


def check_against_baseline(fresh: dict, path: str = OUT_JSON) -> list:
    """Compare fresh results against the committed baseline.  Returns a
    list of human-readable violations (empty = pass)."""
    if not os.path.exists(path):
        return [f"baseline {path} missing — run `benchmarks.run temporal` "
                f"once to create it"]
    with open(path) as f:
        base = json.load(f)
    failures = []
    for (row, field), (kind, bound, rel) in THRESHOLDS.items():
        got = fresh.get(row, {}).get(field)
        if got is None:
            failures.append(f"{row}.{field}: missing from fresh results")
            continue
        ref = base.get(row, {}).get(field)
        if kind == "min":
            floor = bound
            if rel is not None and ref is not None:
                floor = max(bound, rel * ref)
            if got < floor:
                failures.append(
                    f"{row}.{field}: {got:.3f} < floor {floor:.3f} "
                    f"(baseline {'n/a' if ref is None else f'{ref:.3f}'})"
                )
        else:  # max
            if got > bound:
                failures.append(f"{row}.{field}: {got:.3f} > cap {bound:.3f}")
    return failures


# Runs in a subprocess: XLA_FLAGS must be set before jax imports, and the
# in-process benches above need the single real CPU device.
MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import numpy as np, jax
from repro.configs.base import GraphConfig
from repro.core.generator import generate_collection
from repro.core.partition import partition_graph
from repro.core.blocked import build_blocked
from repro.core.engine import TemporalEngine, pagerank_program
from repro.core.algorithms.pagerank import edge_weights_for_instances

cfg = GraphConfig(name="mesh-bench", num_vertices=1024, avg_degree=3.0,
                  num_instances=8, num_partitions=4, block_size=32, seed=7)
tsg = generate_collection(cfg)
tmpl = tsg.template
assign = partition_graph(tmpl, cfg.num_partitions, seed=cfg.seed)
bg = build_blocked(tmpl, assign, cfg.block_size)
I = len(tsg)
active = np.stack([tsg.edge_values(t, "active") for t in range(I)])
w = edge_weights_for_instances(tmpl.src, active, tmpl.num_vertices)
prog = pagerank_program(tmpl.num_vertices, iters=20)
mesh = jax.make_mesh((2, 4), ("data", "model"))
eng_s = TemporalEngine(bg)
eng_m = TemporalEngine(bg, mesh=mesh)


def best(fn, repeats=3):
    fn()
    t = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        t = min(t, time.perf_counter() - t0)
    return t


t_stacked = best(lambda: eng_s.run(prog, w, pattern="independent"))
t_mesh = best(lambda: eng_m.run(prog, w, pattern="independent"))
rs = eng_s.run(prog, w, pattern="independent")
rm = eng_m.run(prog, w, pattern="independent")
assert np.abs(rs.values - rm.values).max() < 1e-6
t_mesh_merge = best(
    lambda: eng_m.run(prog, w, pattern="eventually", merge="mean"))
print(json.dumps({
    "instances": I, "iters": 20, "devices": 8,
    "mesh_shape": {"data": 2, "model": 4},
    "stacked_s": t_stacked, "mesh_s": t_mesh,
    "mesh_eventually_merge_s": t_mesh_merge,
    "mesh_vs_stacked": t_stacked / max(t_mesh, 1e-12),
}))
"""


# Dense all-reduce vs collective-permute ring under shard_map; forced host
# devices need a fresh process (XLA_FLAGS before jax imports).
COMM_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import numpy as np, jax
from repro.configs.base import GraphConfig
from repro.core.generator import generate_collection
from repro.core.partition import partition_graph
from repro.core.blocked import build_blocked
from repro.core.engine import TemporalEngine, pagerank_program
from repro.core.algorithms.pagerank import edge_weights_for_instances

cfg = GraphConfig(name="comm-bench", num_vertices=1024, avg_degree=3.0,
                  num_instances=8, num_partitions=4, block_size=32, seed=7)
tsg = generate_collection(cfg)
tmpl = tsg.template
assign = partition_graph(tmpl, cfg.num_partitions, seed=cfg.seed)
bg = build_blocked(tmpl, assign, cfg.block_size)
I = len(tsg)
active = np.stack([tsg.edge_values(t, "active") for t in range(I)])
w = edge_weights_for_instances(tmpl.src, active, tmpl.num_vertices)
prog = pagerank_program(tmpl.num_vertices, iters=20)
mesh = jax.make_mesh((2, 4), ("data", "model"))
eng_d = TemporalEngine(bg, mesh=mesh)
eng_r = TemporalEngine(bg, mesh=mesh, comm="ring")


def best(fn, repeats=3):
    fn()
    t = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        t = min(t, time.perf_counter() - t0)
    return t


rd = eng_d.run(prog, w, pattern="independent")
rr = eng_r.run(prog, w, pattern="independent")
assert np.abs(rd.values - rr.values).max() < 1e-6  # documented reassociation
t_dense = best(lambda: eng_d.run(prog, w, pattern="independent"))
t_ring = best(lambda: eng_r.run(prog, w, pattern="independent"))
print(json.dumps({
    "instances": I, "iters": 20, "devices": 8,
    "mesh_shape": {"data": 2, "model": 4},
    "dense_s": t_dense, "ring_s": t_ring,
    "ring_vs_dense": t_ring / max(t_dense, 1e-12),
}))
"""


def _comm_mesh_rows() -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    r = subprocess.run(
        [sys.executable, "-c", COMM_MESH_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    if r.returncode != 0:
        emit("temporal/comm_mesh_failed", 0.0, r.stderr.strip()[-200:])
        return {"error": r.stderr.strip()[-2000:]}
    rows = json.loads(r.stdout.strip().splitlines()[-1])
    emit("temporal/comm_dense_mesh", rows["dense_s"] * 1e6,
         f"devices={rows['devices']}")
    emit("temporal/comm_ring_mesh", rows["ring_s"] * 1e6,
         f"ring_vs_dense={rows['ring_vs_dense']:.2f}x")
    return rows


def _cluster_scaling_row() -> dict:
    """2-process localhost cluster run (shard-local staging + real
    inter-process gather) through ``repro.launch.cluster_graph --check``:
    the subprocess asserts bitwise parity with the single-process run and
    per-host staged bytes below it, then prints the byte report."""
    import tempfile
    import time as _time_mod

    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    with tempfile.TemporaryDirectory() as td:
        t0 = _time_mod.perf_counter()
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.cluster_graph",
             "--num-processes", "2", "--apps", "sssp,pagerank",
             "--size", "tiny", "--deploy", os.path.join(td, "gofs"),
             "--out", os.path.join(td, "out"), "--check"],
            env=env, capture_output=True, text=True, timeout=900,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        wall = _time_mod.perf_counter() - t0
    if r.returncode != 0:
        emit("temporal/cluster_scaling_failed", 0.0, r.stderr.strip()[-200:])
        return {"error": (r.stdout + r.stderr).strip()[-2000:]}
    line = next(l for l in r.stdout.splitlines() if "parity OK:" in l)
    report = json.loads(line.split("parity OK:", 1)[1])
    row = {"num_processes": 2, "apps": sorted(report),
           "parity": "bitwise", "wall_s": wall,
           "max_per_host_fraction": 0.0}
    for app, st in report.items():
        single = st["single_staged_bytes"]
        hosts = st["per_host_staged_bytes"]
        row[app] = {
            "single_staged_bytes": single,
            "per_host_staged_bytes": hosts,
            "per_host_fraction": [b / max(single, 1) for b in hosts],
        }
        frac = max(b / max(single, 1) for b in hosts)
        row["max_per_host_fraction"] = max(
            row["max_per_host_fraction"], frac)
        emit(f"temporal/cluster_{app}_staged_frac", frac * 100.0,
             f"per-host bytes / single-process bytes, 2 procs")
    return row


def _mesh_rows() -> dict:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    r = subprocess.run(
        [sys.executable, "-c", MESH_SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    if r.returncode != 0:
        emit("temporal/mesh_failed", 0.0, r.stderr.strip()[-200:])
        return {"error": r.stderr.strip()[-2000:]}
    rows = json.loads(r.stdout.strip().splitlines()[-1])
    emit("temporal/mesh_stacked", rows["stacked_s"] * 1e6,
         f"devices={rows['devices']}")
    emit("temporal/mesh_temporal_parallel", rows["mesh_s"] * 1e6,
         f"mesh_vs_stacked={rows['mesh_vs_stacked']:.2f}x")
    emit("temporal/mesh_eventually_merge",
         rows["mesh_eventually_merge_s"] * 1e6, "")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="compare fresh numbers against the committed "
                         f"{OUT_JSON} (per-row thresholds) and exit "
                         "nonzero on regression instead of rewriting it")
    run(check=ap.parse_args().check)
