"""Benchmark runner: one section per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows.  Usage:
    PYTHONPATH=src python -m benchmarks.run [section ...] [--check]
Sections: gofs_layout sssp_timesteps slices_read engine kernels roofline

``--check`` flips sections that keep a committed baseline (today:
``temporal`` / BENCH_temporal.json) into regression-gate mode — fresh
numbers are compared against the baseline with per-row thresholds and a
violation exits nonzero instead of rewriting the file.
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_engine,
        bench_gofs_layout,
        bench_kernels,
        bench_roofline,
        bench_slices_read,
        bench_sssp_timesteps,
        bench_temporal,
    )

    argv = sys.argv[1:]
    check = "--check" in argv
    argv = [a for a in argv if a != "--check"]

    sections = {
        "gofs_layout": bench_gofs_layout.run,     # paper Fig. 6
        "sssp_timesteps": bench_sssp_timesteps.run,  # paper Fig. 7
        "slices_read": bench_slices_read.run,     # paper Fig. 8
        "engine": bench_engine.run,               # §II/IV superstep economy
        "temporal": lambda: bench_temporal.run(check=check),  # staging+engine
        "kernels": bench_kernels.run,             # §V hot-spot kernels
        "roofline": bench_roofline.run,           # EXPERIMENTS §Roofline
    }
    wanted = argv or list(sections)
    print("name,us_per_call,derived")
    failed = []
    for name in wanted:
        try:
            sections[name]()
        except SystemExit as e:  # --check regression gate
            if e.code:
                failed.append(name)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED sections: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
