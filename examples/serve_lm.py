"""Batched serving example: prefill + decode over a request queue with a
reduced model, exercising the same step functions the production dry-run
compiles at prefill_32k/decode_32k shapes.

  PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.launch.serve import BatchedServer, Request
from repro.models import init_model_params
from repro.train.serve_step import generate


def main() -> None:
    cfg = get_config("glm4-9b").reduced()
    params = init_model_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)

    # low-level: the generate() loop (greedy)
    prompt = rng.integers(0, cfg.vocab_size, (2, 12)).astype(np.int32)
    toks = generate(params, prompt, cfg, max_new_tokens=8)
    print("generate():", np.asarray(toks).tolist())

    # batched server over a queue
    server = BatchedServer(cfg, batch_size=4, max_len=64)
    pf, dc = server.prefill, server.decode
    server.prefill = lambda b: pf(params, b)
    server.decode = lambda b: dc(params, b)
    reqs = [
        Request(rid=i,
                tokens=rng.integers(0, cfg.vocab_size,
                                    rng.integers(4, 16)).astype(np.int32),
                max_new=8)
        for i in range(6)
    ]
    done = server.serve(reqs)
    assert len(done) == 6 and all(len(r.out) == 8 for r in done)
    for r in done[:3]:
        print(f"req {r.rid} ({len(r.tokens)} prompt toks) -> {r.out}")
    print("✓ batched serving")


if __name__ == "__main__":
    main()
