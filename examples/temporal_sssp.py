"""Temporal SSSP under changing traffic (paper §I's motivating example).

A road-grid template with diurnal edge latencies: the shortest path from a
depot evolves across 2-hour instances; the sequentially dependent iBSP
carries distances between timesteps (a vertex only improves as new
conditions are observed — incremental aggregation, §VI-A).

The analytic is declared through the Gopher session API: the session
partitions + blocks the in-memory collection, ``plan()`` resolves every
execution knob (with ``--comm``/``--layout`` as overrides), and one
``run()`` executes the whole sequential pattern.  An explicit
``TemporalEngine`` block follows for contrast — the session must match it
bitwise.

  PYTHONPATH=src python examples/temporal_sssp.py
  PYTHONPATH=src python examples/temporal_sssp.py --comm host   # mesh-free
  PYTHONPATH=src python examples/temporal_sssp.py --comm ring
  PYTHONPATH=src python examples/temporal_sssp.py --layout sparse

Min-plus results are bitwise identical under every backend and layout —
the script asserts it.
"""
import argparse

import numpy as np

from repro.core.graph import (
    AttributeDef, GraphInstance, GraphTemplate, TimeSeriesGraph,
)
from repro.gopher import GopherSession


def road_grid(n: int) -> GraphTemplate:
    ids = np.arange(n * n).reshape(n, n)
    src = np.concatenate([ids[:, :-1].ravel(), ids[:-1, :].ravel(),
                          ids[:, 1:].ravel(), ids[1:, :].ravel()])
    dst = np.concatenate([ids[:, 1:].ravel(), ids[1:, :].ravel(),
                          ids[:, :-1].ravel(), ids[:-1, :].ravel()])
    return GraphTemplate(
        num_vertices=n * n, src=src.astype(np.int64), dst=dst.astype(np.int64),
        edge_attrs=(AttributeDef("travel_time", "float32", default=1.0),),
    )


def main(comm=None, layout=None) -> None:
    n = 32
    tmpl = road_grid(n)
    rng = np.random.default_rng(0)
    instances = []
    for t in range(12):  # one day, 2h windows
        rush = 1.0 + 2.5 * np.exp(-((t - 4) ** 2) / 2) + 2.5 * np.exp(
            -((t - 9) ** 2) / 2)  # two rush hours
        w = (rng.gamma(3.0, 0.4, tmpl.num_edges) * rush).astype(np.float32)
        instances.append(GraphInstance(
            timestamp=t * 7200.0, duration=7200.0,
            edge_values={"travel_time": w},
        ))
    tsg = TimeSeriesGraph(tmpl, instances)

    depot = 0
    # The declarative path: the session partitions + blocks the collection;
    # ONE run executes the whole sequential pattern (the lax.scan carries
    # the distance vector across the instance axis and returns every
    # timestep's state — no O(T^2) re-runs to inspect intermediates).
    # "sssp" is registered over the "latency" attribute; this template
    # calls it "travel_time", so register a tiny alias analytic — the
    # declarative API is extensible, not a closed enum.
    from repro.gopher import REQUIRED, list_analytics, register_analytic

    if "grid_sssp" not in list_analytics():
        @register_analytic(
            "grid_sssp", pattern="sequential", attr="travel_time",
            zero_fill=np.inf, params={"source": REQUIRED},
            postprocess=lambda ctx, res, **_: {"final": res.final},
            describe="temporal SSSP over travel_time",
        )
        def _grid_sssp(ctx, *, source):
            from repro.core.engine import min_plus_program, source_init

            return min_plus_program("sssp", init=source_init(source))

    sess = GopherSession(tsg, num_partitions=4, block_size=64)
    plan = sess.plan("grid_sssp", source=depot, comm=comm, layout=layout)
    print(plan.explain())
    res_a = sess.run(plan)
    res = res_a.engine
    if plan.layout.value == "sparse":
        print(f"✓ block-sparse staging: measured tile occupancy "
              f"{res.occupancy:.1%}")
    print("t  reachable<40min  mean_dist  supersteps")
    for t in range(len(tsg)):
        d_t = res.values[t]
        finite = np.isfinite(d_t)
        print(f"{t:2d}  {int((d_t[finite] < 40).sum()):6d}        "
              f"{d_t[finite].mean():8.2f}   {res.stats['supersteps'][t]}")
    dist = res.final
    # distances only improve over time (incremental aggregation invariant)
    d_first = res.values[0]
    fin = np.isfinite(d_first)
    assert np.all(dist[fin] <= d_first[fin] + 1e-5)
    print("✓ incremental aggregation: final distances <= first-instance distances")

    # Explicit-engine contrast: hand-assemble what plan() chose — the
    # session adds decisions, not semantics, so values match bitwise.
    from repro.core.blocked import build_blocked
    from repro.core.engine import TemporalEngine, min_plus_program, source_init
    from repro.core.partition import partition_graph

    assign = partition_graph(tmpl, 4, seed=0)
    bg = build_blocked(tmpl, assign, 64)
    w = np.stack([tsg.edge_values(t, "travel_time") for t in range(len(tsg))])
    eng = TemporalEngine(bg, comm=plan.comm.value, layout=plan.layout.value)
    res_eng = eng.run(min_plus_program("sssp", init=source_init(depot)), w,
                      pattern="sequential")
    assert np.array_equal(res.values, res_eng.values)
    print(f"✓ session (comm={plan.comm.value}, layout={plan.layout.value}) "
          f"== explicit engine bitwise on every timestep")
    # async staging: instance k+1's tiles fill while instance k executes;
    # the sequential carry crosses chunk boundaries bitwise-identically
    eng_async = TemporalEngine(bg, staging="async", chunk_instances=3,
                               comm=plan.comm.value, layout=plan.layout.value)
    res_async = eng_async.run(
        min_plus_program("sssp", init=source_init(depot)), w,
        pattern="sequential")
    assert np.array_equal(res_async.values, res.values)
    print("✓ double-buffered staging: identical distances, overlapped fills")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--comm", choices=("dense", "ring", "host"),
                    default=None,
                    help="override the planned boundary-exchange backend "
                         "(repro.core.comm)")
    ap.add_argument("--layout", choices=("dense", "sparse"),
                    default=None,
                    help="override the planned tile layout")
    args = ap.parse_args()
    main(comm=args.comm, layout=args.layout)
