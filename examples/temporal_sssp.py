"""Temporal SSSP under changing traffic (paper §I's motivating example).

A road-grid template with diurnal edge latencies: the shortest path from a
depot evolves across 2-hour instances; the sequentially dependent iBSP
carries distances between timesteps (a vertex only improves as new
conditions are observed — incremental aggregation, §VI-A).

  PYTHONPATH=src python examples/temporal_sssp.py
  PYTHONPATH=src python examples/temporal_sssp.py --comm host   # mesh-free
  PYTHONPATH=src python examples/temporal_sssp.py --comm ring
  PYTHONPATH=src python examples/temporal_sssp.py --layout sparse

``--comm`` swaps the boundary-exchange backend (repro.core.comm): min-plus
results are bitwise identical under every backend — the script asserts it.
``--layout sparse`` stages packed active tiles (only roads congested
enough to matter occupy tile memory) and prints the measured occupancy;
results are again bitwise identical — the script asserts that too.
"""
import argparse

import numpy as np

from repro.core.algorithms import sssp
from repro.core.blocked import build_blocked
from repro.core.graph import (
    AttributeDef, GraphInstance, GraphTemplate, TimeSeriesGraph,
)
from repro.core.partition import partition_graph


def road_grid(n: int) -> GraphTemplate:
    ids = np.arange(n * n).reshape(n, n)
    src = np.concatenate([ids[:, :-1].ravel(), ids[:-1, :].ravel(),
                          ids[:, 1:].ravel(), ids[1:, :].ravel()])
    dst = np.concatenate([ids[:, 1:].ravel(), ids[1:, :].ravel(),
                          ids[:, :-1].ravel(), ids[:-1, :].ravel()])
    return GraphTemplate(
        num_vertices=n * n, src=src.astype(np.int64), dst=dst.astype(np.int64),
        edge_attrs=(AttributeDef("travel_time", "float32", default=1.0),),
    )


def main(comm: str = "dense", layout: str = "dense") -> None:
    n = 32
    tmpl = road_grid(n)
    rng = np.random.default_rng(0)
    instances = []
    for t in range(12):  # one day, 2h windows
        rush = 1.0 + 2.5 * np.exp(-((t - 4) ** 2) / 2) + 2.5 * np.exp(
            -((t - 9) ** 2) / 2)  # two rush hours
        w = (rng.gamma(3.0, 0.4, tmpl.num_edges) * rush).astype(np.float32)
        instances.append(GraphInstance(
            timestamp=t * 7200.0, duration=7200.0,
            edge_values={"travel_time": w},
        ))
    tsg = TimeSeriesGraph(tmpl, instances)

    assign = partition_graph(tmpl, 4)
    bg = build_blocked(tmpl, assign, 64)
    w = np.stack([tsg.edge_values(t, "travel_time") for t in range(len(tsg))])

    depot = 0
    # ONE engine run executes the whole sequential pattern: the lax.scan
    # carries the distance vector across the instance axis and returns every
    # timestep's state (no O(T^2) re-runs to inspect intermediates).
    from repro.core.engine import TemporalEngine, min_plus_program, source_init

    print(f"comm backend: {comm} (boundary exchange; see repro.core.comm)")
    print(f"tile layout: {layout} (see repro.core.blocked)")
    eng = TemporalEngine(bg, comm=comm, layout=layout)
    res = eng.run(min_plus_program("sssp", init=source_init(depot)), w,
                  pattern="sequential")
    if layout == "sparse":
        print(f"✓ block-sparse staging: measured tile occupancy "
              f"{res.occupancy:.1%}")
    print("t  reachable<40min  mean_dist  supersteps")
    for t in range(len(tsg)):
        d_t = res.values[t]
        finite = np.isfinite(d_t)
        print(f"{t:2d}  {int((d_t[finite] < 40).sum()):6d}        "
              f"{d_t[finite].mean():8.2f}   {res.stats['supersteps'][t]}")
    dist = res.final
    # distances only improve over time (incremental aggregation invariant)
    d_first = res.values[0]
    fin = np.isfinite(d_first)
    assert np.all(dist[fin] <= d_first[fin] + 1e-5)
    print("✓ incremental aggregation: final distances <= first-instance distances")
    # cross-check against the thin sssp.run_blocked declaration (which runs
    # the DEFAULT dense backend: whatever --comm picked, the distances are
    # bitwise identical — the backend only changes how the bytes move)
    d_ref, _ = sssp.run_blocked(bg, w, depot)
    assert np.allclose(dist[fin], d_ref[fin])
    if comm != "dense" or layout != "dense":
        res_dense = TemporalEngine(bg).run(
            min_plus_program("sssp", init=source_init(depot)), w,
            pattern="sequential")
        assert np.array_equal(res.values, res_dense.values)
        print(f"✓ comm={comm}/layout={layout} == dense bitwise on every "
              f"timestep")
    # async staging: instance k+1's tiles fill while instance k executes;
    # the sequential carry crosses chunk boundaries bitwise-identically
    eng_async = TemporalEngine(bg, staging="async", chunk_instances=3,
                               comm=comm, layout=layout)
    res_async = eng_async.run(
        min_plus_program("sssp", init=source_init(depot)), w,
        pattern="sequential")
    assert np.array_equal(res_async.values, res.values)
    print("✓ double-buffered staging: identical distances, overlapped fills")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--comm", choices=("dense", "ring", "host"),
                    default="dense",
                    help="boundary-exchange backend (repro.core.comm)")
    ap.add_argument("--layout", choices=("dense", "sparse"),
                    default="dense",
                    help="instance tile layout (packed active tiles vs "
                         "dense template tensors)")
    args = ap.parse_args()
    main(comm=args.comm, layout=args.layout)
