"""End-to-end LM training driver with checkpoint/restart, demonstrating the
fault-tolerance contract (kill/resume reproduces the exact stream).

Default is a CPU-sized ~20M model (this container has one core); pass
``--full`` for the ~100M configuration on real hardware.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full]
"""
import argparse
import shutil
import tempfile

from repro.configs import get_config
from repro.launch.train import train_loop
from repro.train.optimizer import OptConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (use on real hardware)")
    args = ap.parse_args()

    if args.full:  # ~100M params: glm4 geometry scaled to d=768/12L
        cfg = get_config("glm4-9b").with_overrides(
            num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32_768, max_seq_len=512,
            remat="none",
        )
    else:  # ~20M: single-core-CPU friendly
        cfg = get_config("glm4-9b").with_overrides(
            num_layers=6, d_model=384, num_heads=6, num_kv_heads=2,
            head_dim=64, d_ff=1024, vocab_size=16_384, max_seq_len=512,
            remat="none",
        )
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.0f}M params "
          f"({cfg.num_layers}L d={cfg.d_model})")

    ckpt_dir = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    oc = OptConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)
    try:
        print(f"== phase 1: train to step {args.steps // 2}, checkpoint, 'crash'")
        out1 = train_loop(
            cfg, steps=args.steps // 2, global_batch=args.batch,
            seq_len=args.seq, oc=oc, ckpt_dir=ckpt_dir,
            ckpt_every=args.steps // 4, log_every=20,
        )
        print("== phase 2: restart from checkpoint, finish the run")
        out2 = train_loop(
            cfg, steps=args.steps, global_batch=args.batch,
            seq_len=args.seq, oc=oc, ckpt_dir=ckpt_dir,
            ckpt_every=args.steps // 4, log_every=20,
        )
        assert out2["resumed_from"] is not None, "must resume, not restart"
        first = out1["history"][0]["loss"]
        last = out2["history"][-1]["loss"]
        print(f"loss {first:.3f} -> {last:.3f} "
              f"(resumed from step {out2['resumed_from']})")
        assert last < first - 0.5, "training must reduce loss"
        print("✓ end-to-end train + checkpoint/restart")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
