"""Vehicle tracking — the paper's Algorithm 1, end to end.

A camera network sees license plates per 2h window; the sequentially
dependent iBSP traces a target vehicle across space (DFS within subgraphs,
messages across) and time (SendToNextTimeStep carries the last sighting).

  PYTHONPATH=src python examples/vehicle_tracking.py
"""
import numpy as np

from repro.configs.base import GraphConfig
from repro.core.algorithms import tracking
from repro.core.blocked import build_blocked
from repro.core.generator import generate_collection
from repro.core.ibsp import InMemoryProvider
from repro.core.partition import discover_subgraphs, partition_graph
from repro.core.subgraph import build_subgraphs


def main() -> None:
    cfg = GraphConfig(
        name="cameras", num_vertices=1_500, avg_degree=3.0,
        num_instances=10, num_partitions=4, block_size=64, seed=9,
    )
    tsg = generate_collection(cfg, num_plates=12)
    tmpl = tsg.template
    plates = np.stack([tsg.vertex_values(t, "plate") for t in range(len(tsg))])

    target = 7
    first_seen = np.nonzero(plates[0] == target)[0]
    start = int(first_seen[0]) if len(first_seen) else 0
    print(f"tracking plate {target} from camera {start}")

    # faithful host engine (Alg. 1: DFS + remote handoff + timestep handoff)
    assign = partition_graph(tmpl, cfg.num_partitions, seed=cfg.seed)
    sg_ids = discover_subgraphs(tmpl, assign)
    subs = build_subgraphs(tmpl, assign, sg_ids)
    prov = InMemoryProvider(tsg, subs, vertex_attrs=("plate",),
                            edge_attrs=("latency",))
    trace_host, res = tracking.run_host(prov, target, start, search_depth=6)
    print("host trace   :", trace_host)
    print(f"  ({res.stats.supersteps} supersteps, "
          f"{res.stats.superstep_messages} cross-subgraph messages)")

    # blocked engine (masked min-plus wavefront) via the session API
    from repro.gopher import GopherSession

    bg = build_blocked(tmpl, assign, cfg.block_size)
    sess = GopherSession.from_blocked(bg, vertex_attrs={"plate": plates})
    trace_blk = sess.run(sess.plan(
        "tracking", plate=target, initial_vertex=start, search_depth=6,
    )).output["trace"]
    print("blocked trace:", trace_blk)
    assert trace_host == trace_blk, "engines must produce the same trace"
    print(f"✓ traced through {len(trace_host)} of {len(tsg)} windows; "
          "engines agree")


if __name__ == "__main__":
    main()
