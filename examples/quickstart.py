"""Quickstart: the GoFFish-JAX pipeline end to end in ~60 seconds.

1. Generate a synthetic time-series graph collection (TR-like, paper §VI-A).
2. Deploy it to GoFS with temporal packing + subgraph binning (paper §V).
3. Run temporal SSSP through the iBSP engine ON the GoFS store (Gopher).
4. Run the same analytics on the TPU-adapted blocked engine and compare.
5. One unified engine, all three iBSP patterns — under any comm backend.
6. Double-buffered GoFS staging: slice reads overlap engine execution.

  PYTHONPATH=src python examples/quickstart.py
  PYTHONPATH=src python examples/quickstart.py --comm host  # mesh-free
  PYTHONPATH=src python examples/quickstart.py --layout sparse

``--comm`` swaps the boundary-exchange backend (dense | ring | host; see
``repro.core.comm``) — identical results, different byte movement.
``--layout sparse`` stages packed active tiles instead of dense template
tensors (``repro.core.blocked.SparseBlocked``) — identical results,
printing the measured tile occupancy.

The paper-to-code map lives in docs/ARCHITECTURE.md; the engine's pattern
contracts and runnable per-pattern snippets are in the docstrings of
``repro.core.engine.TemporalEngine`` / ``SemiringProgram``, the comm
backends' in ``repro.core.comm``, and the staging pipeline's in
``repro.gofs.prefetch.SlicePrefetcher`` (all doctested — see
tests/test_docs.py).
"""
import argparse
import tempfile

import numpy as np

from repro.configs.base import GraphConfig
from repro.core.algorithms import sssp
from repro.core.blocked import build_blocked
from repro.core.generator import generate_collection
from repro.core.partition import edge_cut, partition_graph
from repro.gofs import GoFSStore, deploy_collection


def main(comm: str = "dense", layout: str = "dense") -> None:
    cfg = GraphConfig(
        name="quickstart", num_vertices=2_000, avg_degree=3.0,
        num_instances=6, num_partitions=4, block_size=64,
        instances_per_slice=3, bins_per_partition=4, cache_slots=14, seed=1,
    )
    print("== 1. generate collection")
    tsg = generate_collection(cfg)
    tmpl = tsg.template
    print(f"   V={tmpl.num_vertices} E={tmpl.num_edges} "
          f"instances={len(tsg)} (2h windows)")

    with tempfile.TemporaryDirectory() as root:
        print("== 2. deploy to GoFS", root)
        meta = deploy_collection(tsg, cfg, root)
        print(f"   partitions={meta['num_partitions']} "
              f"instances/slice={meta['instances_per_slice']} "
              f"bins/partition={meta['bins_per_partition']}")

        print("== 3. Gopher iBSP SSSP on GoFS (sequentially dependent)")
        store = GoFSStore(root, cache_slots=14, vertex_projection=(),
                          edge_projection=("latency",))
        dists, res = sssp.run_host(store, source_vertex=0)
        d_host = np.full(tmpl.num_vertices, np.inf)
        for g, d in dists.items():
            d_host[store.get_topology(g).vertices] = d
        print(f"   reached {int(np.isfinite(d_host).sum())} vertices in "
              f"{res.stats.supersteps} supersteps, "
              f"{res.stats.superstep_messages} messages; "
              f"GoFS read {store.stats.slices_read} slices "
              f"({store.cache.stats()['hit_rate']:.0%} cache hits)")

        print("== 4. blocked (TPU-adapted) engine, same analytics")
        assign = partition_graph(tmpl, cfg.num_partitions, seed=cfg.seed)
        print(f"   edge cut: {edge_cut(tmpl, assign)}/{tmpl.num_edges}")
        bg = build_blocked(tmpl, assign, cfg.block_size)
        w = np.stack([tsg.edge_values(t, "latency") for t in range(len(tsg))])
        d_blk, stats = sssp.run_blocked(bg, w, 0)
        print(f"   supersteps/timestep: {stats['supersteps'].tolist()}")
        finite = np.isfinite(d_host)
        assert np.array_equal(np.isfinite(d_blk), finite)
        err = float(np.abs(d_blk[finite] - d_host[finite]).max())
        print(f"   max |blocked - host| = {err:.2e}  ✓ engines agree")

        print(f"== 5. unified temporal engine: one runner, all patterns "
              f"(comm={comm}, layout={layout})")
        from repro.core.engine import (
            TemporalEngine, min_plus_program, pagerank_program, source_init,
        )
        from repro.core.algorithms.pagerank import edge_weights_for_instances

        eng = TemporalEngine(bg, comm=comm, layout=layout)
        # bulk staging: GoFS attribute slices -> (I, P, T, B, B) tensors
        tiles, btiles = store.load_blocked(bg, "latency")
        if layout == "sparse":
            # packed active tiles: same result, O(nnz_tiles) staged bytes
            sp = store.load_blocked(bg, "latency", layout="sparse")
            seq = eng.run(min_plus_program("sssp", init=source_init(0)),
                          sparse=sp, pattern="sequential")
            dense_bytes = tiles.nbytes + btiles.nbytes
            note = ("" if sp.staged_bytes() < dense_bytes else
                    " (every latency is finite here, so every tile is "
                    "live; the sparse win needs temporally sparse "
                    "activity — see the BENCH_temporal.json sparse row)")
            print(f"   block-sparse staging: tile occupancy "
                  f"{seq.occupancy:.1%}, staged bytes "
                  f"{sp.staged_bytes():,} vs dense {dense_bytes:,}{note}")
        else:
            seq = eng.run(min_plus_program("sssp", init=source_init(0)),
                          tiles=tiles, btiles=btiles, pattern="sequential")
        assert np.allclose(seq.final[finite], d_blk[finite])
        if comm != "dense":
            # backend swap is invisible: bitwise-identical to the dense
            # default (the d_blk reference above ran dense)
            dense_seq = TemporalEngine(bg).run(
                min_plus_program("sssp", init=source_init(0)),
                tiles=tiles, btiles=btiles, pattern="sequential")
            assert np.array_equal(seq.values, dense_seq.values)
            print(f"   comm={comm} == dense bitwise  ✓ backend is invisible")
        print(f"   sequential SSSP via engine: {seq.bsp_stats()}")
        active = np.stack([tsg.edge_values(t, "active")
                           for t in range(len(tsg))])
        pw = edge_weights_for_instances(tmpl.src, active, tmpl.num_vertices)
        ev = eng.run(pagerank_program(tmpl.num_vertices, iters=10), pw,
                     pattern="eventually", merge="mean")
        print(f"   eventually PageRank: top vertex over time = "
              f"{int(ev.merged.argmax())}  ✓ one engine, three patterns")

        print("== 6. double-buffered staging: slice reads overlap execution")
        stream = store.load_blocked_stream(bg, "latency", prefetch_depth=2,
                                           layout=layout)
        seq_async = eng.run(min_plus_program("sssp", init=source_init(0)),
                            stream=stream, pattern="sequential")
        assert np.array_equal(seq_async.values, seq.values)
        print(f"   async staging over {len(tsg)} instances "
              f"(chunk = {store.ipack}-instance time packs): results "
              f"bitwise-identical to sync  ✓ staging is invisible")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--comm", choices=("dense", "ring", "host"),
                    default="dense",
                    help="boundary-exchange backend (repro.core.comm)")
    ap.add_argument("--layout", choices=("dense", "sparse"),
                    default="dense",
                    help="instance tile layout: dense template tensors or "
                         "packed active tiles (repro.core.blocked"
                         ".SparseBlocked)")
    args = ap.parse_args()
    main(comm=args.comm, layout=args.layout)
