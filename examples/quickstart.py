"""Quickstart: the GoFFish-JAX pipeline end to end in ~60 seconds.

1. Generate a synthetic time-series graph collection (TR-like, paper §VI-A).
2. Deploy it to GoFS with temporal packing + subgraph binning (paper §V).
3. Run temporal SSSP through the iBSP engine ON the GoFS store (Gopher).
4. The declarative session API: ``GopherSession.plan`` auto-selects
   layout/comm/staging from the deployment's recorded metadata,
   ``explain()`` shows the decisions + cost estimates, ``run()`` executes.
5. The explicit engine, for contrast: the same analytic hand-assembled
   from ``GoFSStore.load_blocked`` + ``TemporalEngine`` — what the
   session automates (and must match bitwise).
6. Shared staging: ``run_many`` executes three analytics staging each
   distinct batch once (the Kairos-style shared-scan amortization —
   SSSP and N-hop share the latency tiles outright).

  PYTHONPATH=src python examples/quickstart.py
  PYTHONPATH=src python examples/quickstart.py --comm host  # mesh-free
  PYTHONPATH=src python examples/quickstart.py --layout sparse

``--comm`` / ``--layout`` override the corresponding planned knobs
(identical results either way — the plan records them as overrides).

The paper-to-code map lives in docs/ARCHITECTURE.md; the session API's
registry → planner → executor walk-through is in its "Gopher session
API" section, and the runnable per-layer snippets are in the docstrings
of ``repro.gopher.session``, ``repro.core.engine.TemporalEngine``, and
``repro.gofs.prefetch.SlicePrefetcher`` (all doctested — see
tests/test_docs.py).
"""
import argparse
import tempfile

import numpy as np

from repro.configs.base import GraphConfig
from repro.core.algorithms import sssp
from repro.core.generator import generate_collection
from repro.gofs import GoFSStore, deploy_collection
from repro.gopher import GopherSession


def main(comm=None, layout=None) -> None:
    cfg = GraphConfig(
        name="quickstart", num_vertices=2_000, avg_degree=3.0,
        num_instances=6, num_partitions=4, block_size=64,
        instances_per_slice=3, bins_per_partition=4, cache_slots=14, seed=1,
    )
    print("== 1. generate collection")
    tsg = generate_collection(cfg)
    tmpl = tsg.template
    print(f"   V={tmpl.num_vertices} E={tmpl.num_edges} "
          f"instances={len(tsg)} (2h windows)")

    with tempfile.TemporaryDirectory() as root:
        print("== 2. deploy to GoFS", root)
        # record nonzero-tile maps for latency: the session's planner
        # prices the sparse layout from these maps without a value read
        meta = deploy_collection(tsg, cfg, root,
                                 sparse_absent={"latency": np.inf})
        print(f"   partitions={meta['num_partitions']} "
              f"instances/slice={meta['instances_per_slice']} "
              f"bins/partition={meta['bins_per_partition']}")

        print("== 3. Gopher iBSP SSSP on GoFS (sequentially dependent)")
        store = GoFSStore(root, cache_slots=14, vertex_projection=(),
                          edge_projection=("latency", "active"))
        dists, res = sssp.run_host(store, source_vertex=0)
        d_host = np.full(tmpl.num_vertices, np.inf)
        for g, d in dists.items():
            d_host[store.get_topology(g).vertices] = d
        print(f"   reached {int(np.isfinite(d_host).sum())} vertices in "
              f"{res.stats.supersteps} supersteps, "
              f"{res.stats.superstep_messages} messages; "
              f"GoFS read {store.stats.slices_read} slices "
              f"({store.cache.stats()['hit_rate']:.0%} cache hits)")

        print("== 4. declarative session API: plan -> explain -> run")
        sess = GopherSession(store)
        plan = sess.plan("sssp", source=0, comm=comm, layout=layout)
        print("\n".join("   " + ln for ln in plan.explain().splitlines()))
        r_sssp = sess.run(plan)
        d_blk = r_sssp.output["final"]
        finite = np.isfinite(d_host)
        assert np.array_equal(np.isfinite(d_blk), finite)
        err = float(np.abs(d_blk[finite] - d_host[finite]).max())
        print(f"   max |session - host| = {err:.2e}  ✓ engines agree")

        print("== 5. the explicit engine, for contrast (what plan() automates)")
        from repro.core.blocked import build_blocked
        from repro.core.engine import (
            TemporalEngine, min_plus_program, source_init,
        )
        from repro.core.partition import edge_cut, partition_graph

        assign = partition_graph(tmpl, cfg.num_partitions, seed=cfg.seed)
        print(f"   edge cut: {edge_cut(tmpl, assign)}/{tmpl.num_edges}")
        bg = build_blocked(tmpl, assign, cfg.block_size)
        eng = TemporalEngine(bg, comm=plan.comm.value,
                             layout=plan.layout.value)
        if plan.layout.value == "sparse":
            sp = store.load_blocked(bg, "latency", layout="sparse")
            seq = eng.run(min_plus_program("sssp", init=source_init(0)),
                          sparse=sp, pattern="sequential")
            print(f"   block-sparse staging: tile occupancy "
                  f"{seq.occupancy:.1%}, staged bytes {sp.staged_bytes():,}")
        else:
            tiles, btiles = store.load_blocked(bg, "latency")
            seq = eng.run(min_plus_program("sssp", init=source_init(0)),
                          tiles=tiles, btiles=btiles, pattern="sequential")
        assert np.array_equal(seq.values, r_sssp.engine.values)
        print("   explicit engine == session bitwise  ✓ the session adds "
              "decisions, not semantics")
        print(f"   sequential SSSP stats: {seq.bsp_stats()}")

        print("== 6. shared staging: three analytics, one pass per batch")
        plans = [
            sess.plan("sssp", source=0, comm=comm, layout=layout),
            sess.plan("nhop", source=0, n_hops=4, comm=comm, layout=layout),
            sess.plan("pagerank", iters=10, comm=comm),
        ]
        many = sess.run_many(plans)
        rep = sess.last_run_report
        print(f"   {len(plans)} analytics "
              f"({', '.join(rep['analytics'])}) staged in "
              f"{rep['staging_passes']} passes, "
              f"{rep['staged_bytes']:,} staged bytes")
        assert np.array_equal(many[0].engine.values, r_sssp.engine.values)
        top = int(many[2].output["ranks"][0].argmax())
        print(f"   sssp identical to the solo run  ✓ sharing is invisible; "
              f"PageRank top vertex (t=0): {top}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--comm", choices=("dense", "ring", "host"),
                    default=None,
                    help="override the planned boundary-exchange backend "
                         "(repro.core.comm; default: planner-selected)")
    ap.add_argument("--layout", choices=("dense", "sparse"),
                    default=None,
                    help="override the planned tile layout "
                         "(repro.core.blocked.SparseBlocked)")
    args = ap.parse_args()
    main(comm=args.comm, layout=args.layout)
